"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scale via REPRO_BENCH_SCALE
(default 0.05 of the paper's dataset sizes; REPRO_BENCH_EPOCHS epochs).

  PYTHONPATH=src python -m benchmarks.run [suite ...]
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import emit_header

SUITES = ("kernels", "replay_throughput", "accuracy", "efficiency",
          "heterogeneity", "privacy", "workers", "batch_size", "ablation",
          "multiparty", "criteo", "cut_placement", "roofline", "chaos",
          "serve_load", "serve_chaos")


def main() -> None:
    want = sys.argv[1:] or list(SUITES)
    emit_header()
    for name in want:
        if name not in SUITES:
            print(f"# unknown suite {name!r}; known: {SUITES}",
                  file=sys.stderr)
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
        except Exception:
            print(f"# suite {name} FAILED:", file=sys.stderr)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
