"""Paper Table 10 (Appendix H): multi-party extension on the Blog dataset.

N-party PubSub-VFL: one active + (N-1) passive parties; planning is done
jointly against the weakest passive party (the appendix's insight).  The
DES approximates the N-party system by the active-vs-weakest two-party
bottleneck with the extra parties' channels adding communication load."""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.core.planner import plan_multiparty
from repro.api import ExperimentConfig

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

PARTIES = [2, 4, 6, 8, 10]


def run() -> None:
    for n in PARTIES:
        for m in ("vfl_ps", "avfl", "avfl_ps", "pubsub"):
            # cores split evenly among parties; weakest passive gets the
            # smallest share (simulating heterogeneous orgs)
            per = 64 // n
            r = run_point(ExperimentConfig(
                method=m, dataset="blog", scale=SCALE,
                n_epochs=EPOCHS, batch_size=64,
                cores_a=per + (64 - per * n), cores_p=max(per - 2, 2),
                jitter=0.1 + 0.02 * n, seed=SEED))
            # communication scales with the number of passive parties
            comm = r["comm_mb"] * max(n - 1, 1) / 1.0
            emit(f"table10/{m}({n})", r["sim_s_per_epoch"] * 1e6,
                 f"rmse={r['final']:.4f};sim_s={r['sim_s'] :.2f};"
                 f"util={r['cpu_util']*100:.2f}%;comm_mb={comm:.1f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
