"""Chaos sweep: crash/straggler severity vs architecture (ISSUE 8).

The paper's claim under test: the Publisher/Subscriber pool absorbs
partial failure (surviving subscribers take over the shared job queue;
a rejoining replica re-enters at the next Eq. 5 sync barrier), while
the paired baselines stall their barrier partners for the whole outage.
We sweep one fault scenario per severity over {pubsub, vfl_ps} and
record accuracy + wall-clock degradation relative to each method's own
healthy run, then re-run the worst straggler under Algorithm 2's
planned (w_a, w_p, B) to answer: does the planner's choice survive a
straggling party?

Fault times are placed at fractions of the method's HEALTHY simulated
duration, so severities are comparable across methods with different
baseline speeds.  Everything lands in `BENCH_fault.json`; CSV rows keep
the harness contract.
"""
from __future__ import annotations

import json
import os

from repro.api import (CrashFault, ExperimentConfig, FaultPlan, Session,
                       StragglerFault)

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

BASE = dict(dataset="credit", scale=SCALE, n_epochs=EPOCHS,
            batch_size=64, w_a=8, w_p=8, seed=SEED)

# (n passive crashes, outage length /T, straggler factor)
SEVERITIES = {
    "mild":     (1, 0.15, 1.5),
    "moderate": (2, 0.30, 2.5),
    "severe":   (3, 0.50, 4.0),
}


def _plan_for(T: float, severity: str) -> FaultPlan:
    n_crash, outage, factor = SEVERITIES[severity]
    crashes = tuple(
        CrashFault(side="p", replica=1 + i, at=(0.2 + 0.1 * i) * T,
                   rejoin_after=outage * T)
        for i in range(n_crash))
    stragglers = (StragglerFault(side="a", replica=0, factor=factor,
                                 start=0.1 * T, ramp=0.2 * T),)
    return FaultPlan(crashes=crashes, stragglers=stragglers)


def _healthy_T(cfg: ExperimentConfig) -> float:
    return Session(cfg).compile().sim.total_time


def _record(name: str, healthy, faulty) -> dict:
    slowdown = faulty["sim_s"] / max(healthy["sim_s"], 1e-12)
    rec = {
        "final": faulty["final"], "final_healthy": healthy["final"],
        "metric": faulty["metric"],
        "acc_drop": healthy["final"] - faulty["final"],
        "sim_s": faulty["sim_s"], "sim_s_healthy": healthy["sim_s"],
        "slowdown": slowdown,
        "staleness": faulty["staleness"],
        "faults": faulty.metrics.get("fault_stats"),
    }
    emit(name, faulty["sim_s_per_epoch"] * 1e6,
         f"{faulty['metric']}={faulty['final']:.4f};"
         f"slowdown={slowdown:.2f}x;"
         f"acc_drop={rec['acc_drop']:+.4f}")
    return rec


def run() -> None:
    out = {"config": {**BASE, "severities": {
        k: dict(zip(("n_crashes", "outage_frac", "straggler_factor"), v))
        for k, v in SEVERITIES.items()}}}

    for method in ("pubsub", "vfl_ps"):
        cfg = ExperimentConfig(method=method, **BASE)
        T = _healthy_T(cfg)
        healthy = run_point(cfg)
        rows = {"healthy": {"final": healthy["final"],
                            "sim_s": healthy["sim_s"], "T": T}}
        for severity in SEVERITIES:
            fp = _plan_for(T, severity)
            sess = Session(ExperimentConfig(method=method, **BASE,
                                            faults=fp),
                           reuse="structural")
            faulty = sess.run()
            faulty.metrics["fault_stats"] = \
                sess.compile().sim.stats["faults"]
            rows[severity] = _record(f"chaos/{method}/{severity}",
                                     healthy, faulty)
        out[method] = rows

    # --- does Algorithm 2's (w_a, w_p, B) survive a straggling party? --
    # "straggling party" = half the passive party's replicas (the
    # planner's bottleneck side) plus one active worker drift to the
    # severe factor — a lone straggler among the planner's
    # over-provisioned actives never touches the critical path
    pcfg = ExperimentConfig(method="pubsub", **{**BASE, "w_a": 4,
                                                "w_p": 4},
                            use_planner=True)
    psess = Session(pcfg)
    T = psess.compile().sim.total_time
    w_p_planned = psess.plan().w_p
    planned_healthy = run_point(pcfg)
    factor = SEVERITIES["severe"][2]
    worst = FaultPlan(stragglers=tuple(
        StragglerFault(side="p", replica=j, factor=factor,
                       start=0.1 * T, ramp=0.2 * T)
        for j in range(max(1, w_p_planned // 2))) + (
        StragglerFault(side="a", replica=0, factor=factor,
                       start=0.1 * T, ramp=0.2 * T),))
    planned_faulty = run_point(ExperimentConfig(
        method="pubsub", **{**BASE, "w_a": 4, "w_p": 4},
        use_planner=True, faults=worst))
    out["planner_under_straggler"] = {
        "plan": planned_healthy["plan"],
        "n_stragglers_p": max(1, w_p_planned // 2),
        **_record("chaos/planner/severe_straggler", planned_healthy,
                  planned_faulty),
    }

    with open("BENCH_fault.json", "w") as fh:
        json.dump(out, fh, indent=2)
    emit("chaos/bench_json", 0.0,
         f"wrote={os.path.abspath('BENCH_fault.json')}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
