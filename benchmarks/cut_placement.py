"""Beyond-paper: cut-layer placement as a planning dimension.

In the paper the parties' workloads are fixed (given bottom models) and
the planner balances with (w_a, w_p, B).  When the backbone is a deep
LLM, the *cut index* itself controls the active/passive compute split —
so the planner gains a fourth knob.  This benchmark sweeps the cut
through an assigned architecture, derives each party's per-batch compute
from the split parameter counts, and runs the PubSub DES: the balanced
cut minimizes simulated step time, exactly as Eq. 4 predicts.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.models.transformer import split_stages

from benchmarks.common import emit

ARCH = "qwen2-0.5b"
FRACTIONS = (0.125, 0.25, 0.5, 0.75, 0.875)


def _stage_params(cfg, stages) -> int:
    sub = cfg.replace(stages=stages,
                      n_layers=sum(r * len(p) for r, p in stages),
                      cut_layer=None)
    # per-layer params only (exclude embed/head): count via layer_specs
    n = 0
    d, hd = sub.d_model, sub.resolved_head_dim
    for mixer, ffn in sub.layer_specs:
        if mixer in ("attn", "local_attn"):
            n += d * sub.n_heads * hd + 2 * d * sub.n_kv_heads * hd \
                + sub.n_heads * hd * d
        if ffn == "dense":
            n += 3 * d * sub.d_ff
    return n


def run() -> None:
    cfg = get_config(ARCH)
    results = []
    for frac in FRACTIONS:
        cut = max(1, min(cfg.n_layers - 1, int(cfg.n_layers * frac)))
        bottom, top = split_stages(cfg.resolved_stages, cut)
        n_b, n_t = _stage_params(cfg, bottom), _stage_params(cfg, top)
        # per-party compute scales with its share of backbone params
        # (the active party additionally runs f_a + the head, folded into
        # the top share); feature_dim is the cost model's scale knob
        total = n_b + n_t
        prof = SystemProfile(
            active=PartyProfile(cores=32, feature_dim=max(int(
                250 * 2 * n_t / total), 1), ref_feature_dim=250),
            passive=PartyProfile(cores=32, feature_dim=max(int(
                250 * 2 * n_b / total), 1), ref_feature_dim=250))
        r = simulate(RunConfig(method="pubsub", n_samples=16384,
                               batch_size=256, n_epochs=2, w_a=8, w_p=8,
                               profile=prof))
        results.append((frac, r))
        emit(f"cut/{ARCH}/frac={frac:g}", r.total_time / 2 * 1e6,
             f"sim_s={r.total_time:.3f};util={r.cpu_util * 100:.1f}%;"
             f"bottom_share={n_b / total:.2f}")
    best = min(results, key=lambda fr: fr[1].total_time)
    emit(f"cut/{ARCH}/best", 0.0,
         f"frac={best[0]:g} (balanced cut minimizes step time)")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
