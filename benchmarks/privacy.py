"""Paper Fig. 5: impact of the GDP privacy budget mu on accuracy, CPU
utilization, communication cost, and defense against embedding-inversion
attacks (ASR)."""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.api import ExperimentConfig

from repro.data.synthetic import load
from repro.data.vertical import vertical_split
from repro.dp.eia import run_eia
from repro.dp.gdp import GDPConfig, noise_sigma
from repro.models import tabular

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

MUS = [0.1, 0.5, 1.0, 2.0, 4.0, 8.0, 10.0, math.inf]


def run() -> None:
    for ds in ("bank", "credit"):
        for mu in MUS:
            r = run_point(ExperimentConfig(
                method="pubsub", dataset=ds, scale=SCALE,
                n_epochs=EPOCHS, batch_size=64, dp_mu=mu, seed=SEED))
            tag = "inf" if math.isinf(mu) else f"{mu:g}"
            emit(f"fig5/{ds}/mu={tag}", r["sim_s_per_epoch"] * 1e6,
                 f"{r['metric']}={r['final']:.4f};"
                 f"util={r['cpu_util']*100:.1f}%;"
                 f"comm_mb={r['comm_mb']:.1f}")

    # EIA defense: ASR vs mu on a trained-at-init passive bottom
    dataset = load("bank", scale=SCALE, seed=SEED)
    _, passive = vertical_split(dataset, seed=SEED)
    theta_p = tabular.init_bottom(jax.random.PRNGKey(SEED),
                                  passive.X.shape[1])
    X = passive.X[:2000]
    for mu in MUS:
        gdp = GDPConfig(mu=mu, clip=1.0, minibatch=64, global_batch=64,
                        n_queries=500)
        asr = run_eia(tabular.passive_forward, theta_p, X,
                      sigma=noise_sigma(gdp), clip=1.0, seed=SEED)
        tag = "inf" if math.isinf(mu) else f"{mu:g}"
        emit(f"fig5/eia/mu={tag}", 0.0,
             f"asr={asr:.3f};sigma={noise_sigma(gdp):.4f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
