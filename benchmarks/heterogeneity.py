"""Paper Fig. 4: computational efficiency under resource heterogeneity
(CPU core ratios 50:14 .. 36:28) and data heterogeneity (feature splits
50:450 .. 200:300), PubSub-VFL vs the strongest baseline."""
from __future__ import annotations

from repro.api import ExperimentConfig

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

CORE_SPLITS = [(50, 14), (48, 16), (40, 24), (36, 28)]
FEATURE_SPLITS = [50, 100, 150, 200]         # active-party features of 500


def run() -> None:
    for ca, cp in CORE_SPLITS:
        for m in ("avfl_ps", "pubsub"):
            r = run_point(ExperimentConfig(
                method=m, dataset="synthetic", scale=max(SCALE * 0.1,
                                                         0.002),
                n_epochs=EPOCHS, batch_size=256, w_a=8, w_p=10,
                cores_a=ca, cores_p=cp, seed=SEED))
            emit(f"fig4/cores{ca}:{cp}/{m}", r["sim_s_per_epoch"] * 1e6,
                 f"sim_s={r['sim_s']:.3f};util={r['cpu_util']*100:.2f}%;"
                 f"wait={r['waiting_per_epoch']:.3f}")
    for fa in FEATURE_SPLITS:
        for m in ("avfl_ps", "pubsub"):
            r = run_point(ExperimentConfig(
                method=m, dataset="synthetic", scale=max(SCALE * 0.1,
                                                         0.002),
                n_epochs=EPOCHS, batch_size=256, w_a=8, w_p=10,
                features_active=fa, seed=SEED))
            emit(f"fig4/feat{fa}:{500 - fa}/{m}",
                 r["sim_s_per_epoch"] * 1e6,
                 f"sim_s={r['sim_s']:.3f};util={r['cpu_util']*100:.2f}%;"
                 f"{r['metric']}={r['final']:.4f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
