"""§Roofline: three-term roofline per (arch x shape) on the single-pod
mesh, derived from the compiled dry-run artifacts (runs/dryrun.jsonl).

  compute term    = HLO_FLOPs(corrected) / peak_FLOPs_chip      [s]
  memory term     = HLO_bytes(corrected) / HBM_bw_chip          [s]
  collective term = collective_bytes(corrected) / ICI_bw_chip   [s]

(dry-run numbers are already per-device; "corrected" = scan trip-count
reconstruction, see launch/dryrun._probe_stage).  Also reports
MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute ratio.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from benchmarks.common import emit

RUNS = os.environ.get("REPRO_DRYRUN_FILE", "runs/dryrun.jsonl")
N_CHIPS = {"single": 256, "multi": 512}


def load_records(path: str = RUNS) -> List[Dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    # keep the LAST record per combo key (re-runs supersede)
    dedup = {}
    for r in out:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("zero", False))] = r
    return list(dedup.values())


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    flops = rec.get("corrected_flops") or rec.get("cost", {}).get("flops",
                                                                  0.0)
    byts = rec.get("corrected_bytes") or rec.get("cost", {}).get(
        "bytes accessed", 0.0)
    coll = rec.get("corrected_collectives") or rec.get("collectives", {})
    coll_bytes = sum(v for k, v in coll.items() if k != "count")
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    shape = SHAPES[rec["shape"]]
    if shape.kind == "decode":
        tokens = shape.global_batch
        mult = 2.0                                  # forward only
    else:
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0 if shape.kind == "train" else 2.0
    n = rec.get("n_active_params") or rec.get("n_params", 0)
    model_flops = mult * n * tokens / N_CHIPS[rec["mesh"]]
    ratio = model_flops / flops if flops else 0.0
    return {
        **terms, "dominant": dominant, "model_flops": model_flops,
        "useful_ratio": ratio, "flops": flops, "bytes": byts,
        "coll_bytes": coll_bytes,
        "bound_s": max(terms.values()),
    }


def run() -> None:
    recs = [r for r in load_records() if r.get("mesh") == "single"
            and not r.get("zero", False)]
    if not recs:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all --out "
             "runs/dryrun.jsonl` first")
        return
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("status") == "skipped":
            emit(name, 0.0, f"skipped:{r.get('note', '')}")
            continue
        t = roofline_terms(r)
        if t is None:
            emit(name, 0.0, f"error:{r.get('error', '?')[:80]}")
            continue
        emit(name, t["bound_s"] * 1e6,
             f"compute={t['compute']:.3e}s;memory={t['memory']:.3e}s;"
             f"collective={t['collective']:.3e}s;dominant={t['dominant']};"
             f"useful={t['useful_ratio']:.2f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
