"""Paper Table 4: ablation studies — w/o waiting deadline (T_all), w/o the
DP planning algorithm, w/o the semi-async interval (Delta T), w/o PubSub
(replaced by AVFL-PS), and combinations; evaluated on all five datasets
under a heterogeneous, jittery profile so the mechanisms matter."""
from __future__ import annotations

from repro.api import ExperimentConfig

from repro.data.synthetic import DATASETS

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

VARIANTS = {
    "all": {},
    "wo_Tall": {"disable_deadline": True},
    "wo_DP_algo": {"disable_planner": True, "use_planner": True},
    "wo_dT": {"disable_semi_async": True},
    "wo_PubSub": {"method": "avfl_ps"},
    "wo_Tall_and_dT": {"disable_deadline": True,
                       "disable_semi_async": True},
}


def run() -> None:
    for ds in DATASETS:
        sc = SCALE if ds not in ("synthetic",) else max(SCALE * 0.1, 0.002)
        for name, kw in VARIANTS.items():
            base = dict(method="pubsub", dataset=ds, scale=sc,
                        n_epochs=EPOCHS, batch_size=64,
                        cores_a=40, cores_p=24, jitter=0.25,
                        use_planner=True, seed=SEED)
            base.update(kw)
            r = run_point(ExperimentConfig(**base))
            emit(f"table4/{ds}/{name}", r["sim_s_per_epoch"] * 1e6,
                 f"{r['metric']}={r['final']:.4f};sim_s={r['sim_s']:.2f};"
                 f"util={r['cpu_util']*100:.1f}%")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
