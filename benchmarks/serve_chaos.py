"""Serve-side chaos benchmark (→ BENCH_serve.json ``"chaos"`` record).

Poisson load through the continuous-batching engine while a
deterministic `ServeFaultPlan` degrades it — straggler drift, one-off
stalls, transient step failures, fatal engine crashes and poisoned
requests — per severity, with a bounded queue and per-request
deadlines so overload shows up as admission-control shed instead of
silent latency collapse.  `run_with_recovery` rebuilds the engine after
each crash and replays the in-flight requests from their prompts.

Recorded per severity (the serving twin of ``benchmarks/chaos.py``):
goodput (fraction of offered requests finishing "length"/"eos" inside
their deadline), shed rate (queue rejections + expired), restart count
and recovery latency, and the **replay-parity assertion** — every
completed request's tokens must equal the fault-free oracle run,
crashes included.

  PYTHONPATH=src python -m benchmarks.serve_chaos [--smoke]

--smoke: one crash severity, no deadlines/bounds (every request must
complete), asserting all futures resolved, >= 1 recovery, exactly one
compiled decode program, and 100% replay parity; exits non-zero
otherwise (the CI serve-chaos step).
"""
from __future__ import annotations

import os
import sys
import threading
import time

import numpy as np

from repro.serve import (QueueFull, Request, ServeEngine, ServeFaultPlan,
                         StepStall, StragglerDrift, open_loop,
                         run_with_recovery, synthetic_requests)

from benchmarks.common import SEED, emit, emit_header, merge_bench_json

ARCH = os.environ.get("REPRO_SERVE_ARCH", "qwen2-0.5b")
N_REQUESTS = int(os.environ.get("REPRO_SERVE_REQUESTS", "24"))
SLOTS = int(os.environ.get("REPRO_SERVE_SLOTS", "8"))
GEN = int(os.environ.get("REPRO_SERVE_GEN", "16"))
PROMPT_LENS = (4, 12)
CACHE_CAP = PROMPT_LENS[1] + GEN
QPS = float(os.environ.get("REPRO_SERVE_QPS", "64"))
QUEUE_CAP = int(os.environ.get("REPRO_SERVE_QUEUE_CAP", "8"))
DEADLINE_S = float(os.environ.get("REPRO_SERVE_DEADLINE_S", "2.0"))
MAX_RESTARTS = 5

# fault severities: drift/stall latency injection grows, transient step
# failures multiply, then fatal crashes (one per engine incarnation)
# and a poisoned request join in
SEVERITIES = {
    "mild": ServeFaultPlan(
        drift=StragglerDrift(start_step=0, per_step_s=2e-4, cap_s=0.01),
        stalls=(StepStall(at_step=5, stall_s=0.05),),
        step_fails=(7,)),
    "moderate": ServeFaultPlan(
        drift=StragglerDrift(start_step=0, per_step_s=5e-4, cap_s=0.02),
        stalls=(StepStall(at_step=8, stall_s=0.1),),
        step_fails=(5, 12), crashes=(15,)),
    "severe": ServeFaultPlan(
        drift=StragglerDrift(start_step=0, per_step_s=1e-3, cap_s=0.03),
        stalls=(StepStall(at_step=6, stall_s=0.15),
                StepStall(at_step=20, stall_s=0.15)),
        step_fails=(4, 11, 18), crashes=(12, 10), poison_rids=(3,)),
}


def _requests(vocab: int, n: int, deadline_s):
    return synthetic_requests(n, vocab, seed=SEED, prompt_lens=PROMPT_LENS,
                              max_new_tokens=GEN, deadline_s=deadline_s)


def _oracle(params, vocab: int, n: int) -> dict:
    """Fault-free tokens per request seed (seeds are unique per request:
    the stable join key between a chaos completion and its oracle)."""
    eng = ServeEngine(ARCH, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED,
                      params=params)
    done = eng.serve(_requests(vocab, n, None))
    reqs = _requests(vocab, n, None)
    return {reqs[c.rid].seed: c.tokens for c in done}


def bench_severity(name: str, plan: ServeFaultPlan, params, vocab: int,
                   oracle: dict, n: int) -> dict:
    eng = ServeEngine(ARCH, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED,
                      params=params, faults=plan)
    queue = eng.queue(capacity=QUEUE_CAP, policy="reject")
    reqs = _requests(vocab, n, DEADLINE_S)
    gaps = np.random.default_rng(SEED).exponential(1.0 / QPS, size=n)
    accepted: dict = {}              # rid -> Request
    counts = {"offered": 0, "rejected": 0}

    def generator():
        for req, gap in zip(reqs, gaps):
            time.sleep(gap)
            counts["offered"] += 1
            try:
                queue.submit(req)
                accepted[req.rid] = req
            except QueueFull:
                counts["rejected"] += 1
        queue.close()

    t = threading.Thread(target=generator, daemon=True)
    t.start()
    t0 = time.perf_counter()
    res = run_with_recovery(eng, queue, max_restarts=MAX_RESTARTS,
                            backoff_s=0.01)
    wall = time.perf_counter() - t0
    t.join()

    done = res.completions
    by_reason: dict = {}
    for c in done:
        by_reason[c.finish_reason] = by_reason.get(c.finish_reason, 0) + 1
    ok = [c for c in done if c.ok]
    expired = by_reason.get("expired", 0)
    parity_ok = sum(c.tokens == oracle[accepted[c.rid].seed] for c in ok)
    stats = res.engine.last_run_stats
    row = {
        "faults": plan.to_dict(),
        "offered": counts["offered"],
        "rejected": counts["rejected"],
        "submitted": counts["offered"] - counts["rejected"],
        "completed": len(done),
        "by_finish_reason": by_reason,
        "goodput": len(ok) / max(counts["offered"], 1),
        "shed_rate": (counts["rejected"] + expired)
        / max(counts["offered"], 1),
        "restarts": res.restarts,
        "recovery_s": list(res.recovery_s),
        "recovery_p50_ms": (float(np.median(res.recovery_s)) * 1e3
                            if res.recovery_s else 0.0),
        "replay_parity": {"checked": len(ok), "matched": parity_ok},
        "wall_s": wall,
        "gen_tokens": sum(len(c.tokens) for c in done),
        "decode_compiles": stats["decode_compiles"],
    }
    emit(f"serve_chaos/{ARCH}/{name}", wall * 1e6 / max(n, 1),
         f"goodput={row['goodput']:.2f};shed={row['shed_rate']:.2f};"
         f"restarts={res.restarts};"
         f"parity={parity_ok}/{len(ok)}")
    return row


def validate(rows: dict) -> list:
    """Hard contract of the chaos record: every future resolved, every
    completed request token-for-token equal to the fault-free run."""
    errors = []
    for name, row in rows.items():
        if row["completed"] != row["submitted"]:
            errors.append(
                f"{name}: {row['completed']}/{row['submitted']} "
                "submitted requests resolved (futures hang?)")
        p = row["replay_parity"]
        if p["matched"] != p["checked"]:
            errors.append(
                f"{name}: replay parity broke "
                f"({p['matched']}/{p['checked']} token-identical)")
        if row["decode_compiles"] != 1:
            errors.append(f"{name}: {row['decode_compiles']} decode "
                          "compiles (want exactly 1 per shape)")
    return errors


def run(*, severities=None, n_requests: int = N_REQUESTS,
        check: bool = False) -> dict:
    severities = severities or SEVERITIES
    probe = ServeEngine(ARCH, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED)
    vocab, params = probe.cfg.vocab_size, probe.params
    probe.serve(_requests(vocab, 1, None))        # warm the slot program
    oracle = _oracle(params, vocab, n_requests)

    rows = {}
    for name, plan in severities.items():
        rows[name] = bench_severity(name, plan, params, vocab, oracle,
                                    n_requests)

    out = {"config": {
        "arch": ARCH, "n_requests": n_requests, "slots": SLOTS,
        "gen": GEN, "prompt_lens": list(PROMPT_LENS),
        "cache_cap": CACHE_CAP, "qps": QPS, "queue_cap": QUEUE_CAP,
        "deadline_s": DEADLINE_S, "max_restarts": MAX_RESTARTS,
        "seed": SEED,
    }, "severities": rows}
    merge_bench_json("BENCH_serve.json", {"chaos": out})
    emit("serve_chaos/bench_json", 0.0,
         f"wrote={os.path.abspath('BENCH_serve.json')}")

    errors = validate(rows)
    for e in errors:
        print(f"# serve chaos FAIL: {e}", file=sys.stderr)
    if check and errors:
        raise SystemExit(1)
    return out


def smoke() -> None:
    """CI leg: crash mid-batch, recover, and prove nothing was lost —
    no deadlines and an unbounded queue, so EVERY offered request must
    come back ok and token-identical to the fault-free oracle."""
    n = 6
    probe = ServeEngine(ARCH, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED)
    vocab, params = probe.cfg.vocab_size, probe.params
    probe.serve(_requests(vocab, 1, None))
    oracle = _oracle(params, vocab, n)

    plan = ServeFaultPlan(step_fails=(3,), crashes=(8,))
    eng = ServeEngine(ARCH, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED,
                      params=params, faults=plan)
    reqs = _requests(vocab, n, None)
    events: dict = {}
    done = open_loop(eng, reqs, qps=200.0, seed=SEED,
                     queue=eng.queue(), recover=True,
                     max_restarts=MAX_RESTARTS, events=events)

    failures = []
    if len(done) != n:
        failures.append(f"{len(done)}/{n} futures resolved")
    if not all(c.ok for c in done):
        failures.append("non-ok completion under recoverable faults: "
                        f"{[c.finish_reason for c in done]}")
    if events.get("restarts", 0) < 1:
        failures.append("injected crash did not trigger a recovery")
    bad = [c.rid for c in done
           if c.tokens != oracle[reqs[c.rid].seed]]
    if bad:
        failures.append(f"replay parity broke for rids {bad}")
    stats = eng.last_run_stats or {}
    if stats.get("decode_compiles", 1) != 1:
        failures.append(f"{stats['decode_compiles']} decode compiles")
    for f in failures:
        print(f"# serve chaos smoke FAIL: {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    emit("serve_chaos/smoke", 0.0,
         f"restarts={events['restarts']};parity={n}/{n}")


if __name__ == "__main__":
    emit_header()
    if "--smoke" in sys.argv:
        smoke()
    else:
        run(check="--check" in sys.argv)
