"""Shared benchmark helpers: CSV emission, scaled defaults, and the
Session-backed experiment runner.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) where `us_per_call` is the simulated per-iteration latency in
microseconds and `derived` carries the table's headline quantity.

Benchmarks run experiment points through `run_point` (the Session API
with structural program reuse): points sharing a compiled shape —
repeated methods across datasets of one shape, DP grids, seed repeats —
pay data prep + DES + schedule lowering + XLA tracing once per shape
instead of once per point.  `run_point` returns a
`repro.api.RunResult`, which supports the legacy `r["key"]` dict access
plus `r.train` (the TrainResult, e.g. `epochs_to_target`) and
`r.wall_s` / `r.compile_cache_hit`.
"""
from __future__ import annotations

import json
import os
import resource
import sys
import time
from typing import Dict, Iterable, List

from repro.api import ExperimentConfig, RunResult, Session

# dataset scale for benchmarks (1.0 = paper-size; CI default small)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.05"))
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "5"))
SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))


def peak_host_mb() -> float:
    """Process peak RSS in MB (ru_maxrss is KB on Linux).  A high-water
    mark: it never decreases, so per-row readings in a multi-row run
    reflect the largest-footprint row so far."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_point(cfg: ExperimentConfig, *, reuse: str = "structural"
              ) -> RunResult:
    """One sweep point through the Session lifecycle, reusing any
    already-compiled same-shape program.  The result's metrics gain
    `peak_host_mb` — the process-wide peak RSS after the run — so the
    memory footprint of the data path is visible on every row."""
    r = Session(cfg, reuse=reuse).run()
    r.metrics["peak_host_mb"] = peak_host_mb()
    return r


def merge_bench_json(path: str, updates: Dict) -> Dict:
    """Update top-level keys of a JSON bench record in place, keeping
    the keys other suites own (`serve_load` writes config/archs,
    `serve_chaos` writes chaos — both into BENCH_serve.json)."""
    rec = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                rec = json.load(fh)
        except (OSError, json.JSONDecodeError):
            rec = {}                 # torn/legacy record: start fresh
    rec.update(updates)
    with open(path, "w") as fh:
        json.dump(rec, fh, indent=2)
    return rec


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def emit_header() -> None:
    print("name,us_per_call,derived", flush=True)


def us_per_iter(result: dict) -> float:
    """Simulated seconds/epoch -> us per training iteration."""
    n_iters = max(len(result.get("losses", [1])), 1)
    per_epoch = result["sim_s_per_epoch"]
    n_batches = max(result.get("n_batches", 1), 1)
    return per_epoch * 1e6 / max(n_batches, 1)
