"""Shared benchmark helpers: CSV emission + scaled defaults.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) where `us_per_call` is the simulated per-iteration latency in
microseconds and `derived` carries the table's headline quantity.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Iterable, List

# dataset scale for benchmarks (1.0 = paper-size; CI default small)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "0.05"))
EPOCHS = int(os.environ.get("REPRO_BENCH_EPOCHS", "5"))
SEED = int(os.environ.get("REPRO_BENCH_SEED", "0"))


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def emit_header() -> None:
    print("name,us_per_call,derived", flush=True)


def us_per_iter(result: dict) -> float:
    """Simulated seconds/epoch -> us per training iteration."""
    n_iters = max(len(result.get("losses", [1])), 1)
    per_epoch = result["sim_s_per_epoch"]
    n_batches = max(result.get("n_batches", 1), 1)
    return per_epoch * 1e6 / max(n_batches, 1)
