"""Poisson-load serving benchmark (→ BENCH_serve.json).

Open-loop arrivals at swept offered QPS through the continuous-batching
split-inference engine, per architecture: p50/p99 TTFT, p50/p99
inter-token latency, generated tokens/s, and slot occupancy — plus a
serial per-request baseline (slot_count=1, one request at a time) that
continuous batching must beat on tokens/s at the highest QPS point.

Archs cover the cache zoo the training path never touches: qwen2
(GQA KV ring), phi4-mini (GQA KV ring, deeper reduced stack),
recurrentgemma (rglru recurrent state + local-attn KV ring), rwkv6
(wkv matrix state + token-shift regs).

  PYTHONPATH=src python -m benchmarks.serve_load [--smoke]

--smoke: one arch, two QPS points, few requests; exits non-zero unless
every request completes, the engine compiled exactly one decode program,
and the BENCH_serve.json record is well-formed (the CI serve step).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

from repro.serve import ServeEngine, open_loop, synthetic_requests

from benchmarks.common import SEED, emit, emit_header, merge_bench_json

ARCHS = ("qwen2-0.5b", "phi4-mini-3.8b", "recurrentgemma-9b", "rwkv6-1.6b")
QPS_POINTS = (4.0, 16.0, 64.0)
N_REQUESTS = int(os.environ.get("REPRO_SERVE_REQUESTS", "24"))
SLOTS = int(os.environ.get("REPRO_SERVE_SLOTS", "8"))
GEN = int(os.environ.get("REPRO_SERVE_GEN", "16"))
PROMPT_LENS = (4, 12)
CACHE_CAP = PROMPT_LENS[1] + GEN


def pct(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def _requests(vocab: int, n: int):
    return synthetic_requests(n, vocab, seed=SEED, prompt_lens=PROMPT_LENS,
                              max_new_tokens=GEN)


def _summarize(done, wall_s: float, stats: dict) -> dict:
    ttft = [c.ttft_s for c in done]
    itl = [c.per_token_s for c in done if len(c.tokens) > 1]
    gen_tokens = sum(len(c.tokens) for c in done)
    return {
        "completed": len(done),
        "gen_tokens": gen_tokens,
        "wall_s": wall_s,
        "tokens_per_s": gen_tokens / max(wall_s, 1e-9),
        "ttft_p50_ms": pct(ttft, 50) * 1e3,
        "ttft_p99_ms": pct(ttft, 99) * 1e3,
        "itl_p50_ms": pct(itl, 50) * 1e3,
        "itl_p99_ms": pct(itl, 99) * 1e3,
        "occupancy": stats["occupancy"],
        "decode_compiles": stats["decode_compiles"],
    }


def bench_arch(arch: str, qps_points, n_requests: int) -> dict:
    eng = ServeEngine(arch, slots=SLOTS, cache_cap=CACHE_CAP, seed=SEED)
    vocab = eng.cfg.vocab_size

    # serial per-request baseline: same request mix, one at a time
    serial = ServeEngine(arch, slots=1, cache_cap=CACHE_CAP, seed=SEED,
                         params=eng.params)
    # warm both programs so measured TTFT is steady-state, not compile
    for e in (eng, serial):
        e.serve(_requests(vocab, 1))
    reqs = _requests(vocab, n_requests)
    t0 = time.perf_counter()
    done = []
    for r in reqs:                      # closed loop, batch of one
        done.extend(serial.serve([r]))
    serial_row = _summarize(done, time.perf_counter() - t0,
                            serial.stats)
    emit(f"serve/{arch}/serial", serial_row["wall_s"] * 1e6 / n_requests,
         f"tok_s={serial_row['tokens_per_s']:.1f}")

    points = []
    for qps in qps_points:
        reqs = _requests(vocab, n_requests)
        t0 = time.perf_counter()
        done = open_loop(eng, reqs, qps, seed=SEED)
        row = _summarize(done, time.perf_counter() - t0,
                         eng.last_run_stats)
        row["offered_qps"] = qps
        row["speedup_vs_serial"] = (row["tokens_per_s"]
                                    / max(serial_row["tokens_per_s"], 1e-9))
        points.append(row)
        emit(f"serve/{arch}/qps{qps:g}", row["wall_s"] * 1e6 / n_requests,
             f"tok_s={row['tokens_per_s']:.1f};"
             f"ttft_p50={row['ttft_p50_ms']:.1f}ms;"
             f"ttft_p99={row['ttft_p99_ms']:.1f}ms;"
             f"occ={row['occupancy']:.2f};"
             f"x_serial={row['speedup_vs_serial']:.2f}")

    return {"serial": serial_row, "points": points,
            "slots": SLOTS, "cache_cap": CACHE_CAP}


def validate(out: dict) -> list:
    """Well-formedness of the BENCH_serve.json record (CI contract)."""
    errors = []
    for arch, rows in out["archs"].items():
        want = ("tokens_per_s", "ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms",
                "itl_p99_ms", "occupancy", "offered_qps", "completed",
                "decode_compiles")
        for row in rows["points"]:
            missing = [k for k in want if k not in row]
            if missing:
                errors.append(f"{arch}: missing {missing}")
            if row["completed"] != out["config"]["n_requests"]:
                errors.append(
                    f"{arch}@{row['offered_qps']}qps: "
                    f"{row['completed']}/{out['config']['n_requests']} "
                    "requests completed")
            if row["decode_compiles"] != 1:
                errors.append(
                    f"{arch}@{row['offered_qps']}qps: "
                    f"{row['decode_compiles']} decode compiles "
                    "(want exactly 1 per shape)")
        top = rows["points"][-1]
        if top["speedup_vs_serial"] <= 1.0:
            errors.append(
                f"{arch}: continuous batching does not beat serial at "
                f"{top['offered_qps']} qps "
                f"({top['speedup_vs_serial']:.2f}x)")
    return errors


def run(*, archs=ARCHS, qps_points=QPS_POINTS, n_requests=N_REQUESTS,
        check: bool = False) -> dict:
    out = {"config": {
        "n_requests": n_requests, "slots": SLOTS, "gen": GEN,
        "prompt_lens": list(PROMPT_LENS), "cache_cap": CACHE_CAP,
        "qps_points": list(qps_points), "seed": SEED,
    }, "archs": {}}
    for arch in archs:
        out["archs"][arch] = bench_arch(arch, qps_points, n_requests)

    # merge, don't overwrite: serve_chaos.py owns the "chaos" key
    merge_bench_json("BENCH_serve.json", out)
    emit("serve/bench_json", 0.0,
         f"wrote={os.path.abspath('BENCH_serve.json')}")

    errors = validate(out)
    for e in errors:
        print(f"# serve bench FAIL: {e}", file=sys.stderr)
    if check and errors:
        raise SystemExit(1)
    return out


if __name__ == "__main__":
    emit_header()
    if "--smoke" in sys.argv:
        run(archs=("qwen2-0.5b",), qps_points=(8.0, 64.0), n_requests=6,
            check=True)
    else:
        run(check="--check" in sys.argv)
