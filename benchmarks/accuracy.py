"""Paper Table 1 (small bottom model) and Table 7 (large/ResNet bottom):
accuracy comparison across the five datasets and five methods."""
from __future__ import annotations

from repro.core.runtime import ExperimentConfig, run_experiment
from repro.data.synthetic import DATASETS

from benchmarks.common import EPOCHS, SCALE, SEED, emit

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")


def run(large: bool = False) -> None:
    table = "table7" if large else "table1"
    for ds in DATASETS:
        for m in METHODS:
            r = run_experiment(ExperimentConfig(
                method=m, dataset=ds, scale=SCALE, n_epochs=EPOCHS,
                batch_size=64, seed=SEED, resnet=large,
                depth=18 if large else 10))
            us = r["sim_s_per_epoch"] * 1e6
            emit(f"{table}/{ds}/{m}", us,
                 f"{r['metric']}={r['final']:.4f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
