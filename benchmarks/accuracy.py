"""Paper Table 1 (small bottom model) and Table 7 (large/ResNet bottom):
accuracy comparison across the five datasets and five methods."""
from __future__ import annotations

import math

from repro.api import ExperimentConfig
from repro.data.synthetic import DATASETS

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")
TARGET_AUC = 0.90       # convergence-speed companion to the accuracy row


def run(large: bool = False) -> None:
    table = "table7" if large else "table1"
    for ds in DATASETS:
        for m in METHODS:
            r = run_point(ExperimentConfig(
                method=m, dataset=ds, scale=SCALE, n_epochs=EPOCHS,
                batch_size=64, seed=SEED, resnet=large,
                depth=18 if large else 10))
            us = r["sim_s_per_epoch"] * 1e6
            # math.inf when the target is never reached (distinct from
            # "reached on the last epoch" — see TrainResult)
            ep_to = r.train.epochs_to_target(
                TARGET_AUC, higher_better=r["metric"] == "auc")
            tag = "inf" if math.isinf(ep_to) else f"{ep_to:.0f}"
            emit(f"{table}/{ds}/{m}", us,
                 f"{r['metric']}={r['final']:.4f};"
                 f"epochs_to_{TARGET_AUC}={tag}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
