"""Kernel micro-benchmarks: wall time of the jnp reference path on this
CPU (the TPU kernel is validated in interpret mode; wall-clock TPU numbers
require hardware).  `derived` reports achieved GFLOP/s / GB/s on CPU."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.cut_layer.kernel import cut_layer_pallas
from repro.kernels.cut_layer.ref import cut_layer_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rglru_scan.ref import rglru_scan_assoc_ref
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref

from benchmarks.common import emit


def _bench(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    B, S, Hq, Hk, D = 1, 1024, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True,
                                                     window=None))
    t = _bench(fa, q, k, v)
    flops = 4 * B * Hq * S * S * D
    emit("kernel/flash_attention_ref", t * 1e6,
         f"gflops={flops / t / 1e9:.2f}")

    B, S, H, D = 1, 512, 4, 32
    r = jax.random.normal(ks[3], (B, S, H, D))
    kk = jax.random.normal(ks[4], (B, S, H, D))
    vv = jax.random.normal(ks[5], (B, S, H, D))
    w = jax.nn.sigmoid(jax.random.normal(ks[6], (B, S, H, D)))
    u = jax.random.normal(ks[7], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    rw = jax.jit(rwkv6_scan_ref)
    t = _bench(rw, r, kk, vv, w, u, s0)
    flops = 4 * B * S * H * D * D
    emit("kernel/rwkv6_scan_ref", t * 1e6,
         f"gflops={flops / t / 1e9:.2f}")

    B, S, W = 4, 2048, 512
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    uu = jax.random.normal(ks[1], (B, S, W))
    h0 = jnp.zeros((B, W))
    rg = jax.jit(rglru_scan_assoc_ref)
    t = _bench(rg, a, uu, h0)
    emit("kernel/rglru_scan_assoc", t * 1e6,
         f"gbps={B * S * W * 4 * 3 / t / 1e9:.2f}")

    M, K, N = 512, 512, 128
    x = jax.random.normal(ks[2], (M, K))
    wm = jax.random.normal(ks[3], (K, N)) * 0.05
    b = jnp.zeros((N,))
    nz = jax.random.normal(ks[4], (M, N))
    cl = jax.jit(lambda x, w, b, n: cut_layer_ref(x, w, b, n, clip=1.0,
                                                  sigma=0.1))
    t = _bench(cl, x, wm, b, nz)
    emit("kernel/cut_layer_ref", t * 1e6,
         f"gflops={2 * M * K * N / t / 1e9:.2f}")

    # the fused Pallas kernel (interpret mode off-TPU): the number is a
    # correctness/lowering smoke-bench on CPU — HW numbers need a TPU,
    # where interpret auto-disables — reported relative to the ref path
    t_p = _bench(cut_layer_pallas, x, wm, b, nz, clip=1.0, sigma=0.1)
    emit("kernel/cut_layer_pallas", t_p * 1e6,
         f"gflops={2 * M * K * N / t_p / 1e9:.2f};vs_ref_x={t / t_p:.3f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
