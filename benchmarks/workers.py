"""Paper Table 2: effect of the number of workers (w_a = w_p, B=32)."""
from __future__ import annotations

from repro.api import ExperimentConfig

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

WORKERS = [4, 5, 8, 10, 20, 30, 50]


def run() -> None:
    for w in WORKERS:
        r = run_point(ExperimentConfig(
            method="pubsub", dataset="synthetic",
            scale=max(SCALE * 0.1, 0.002), n_epochs=EPOCHS,
            batch_size=32, w_a=w, w_p=w, seed=SEED))
        emit(f"table2/w={w}", r["sim_s_per_epoch"] * 1e6,
             f"auc={r['final']:.4f};sim_s={r['sim_s']:.2f};"
             f"util={r['cpu_util']*100:.2f}%;"
             f"wait={r['waiting_per_epoch']:.4f};comm_mb={r['comm_mb']:.1f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
