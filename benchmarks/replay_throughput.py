"""Replay-engine throughput: compiled jitted-scan engine vs. the legacy
per-event Python loop, on the synthetic `pubsub` configuration.

Reports, per engine: steady-state wall-clock per epoch and replayed
events/sec.  For the compiled engine the one-time cost (schedule
compilation + jit trace + XLA compile, paid once per process & shape) is
measured separately and reported as `replay/compiled_cold`; the
steady-state number is the second replay, which hits the process-wide
runner cache — the regime any multi-run experiment (sweeps, epochs at
scale) actually sits in.  The event engine is likewise measured after
its first replay has warmed the per-op jit caches.

Scale knobs (env): REPRO_BENCH_SCALE (dataset fraction, default 0.05),
REPRO_BENCH_EPOCHS (default 5).
"""
from __future__ import annotations

import time

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.core.trainer import VFLTrainer
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split

from benchmarks.common import EPOCHS, SCALE, SEED, emit


def _build(method: str = "pubsub"):
    ds = load("synthetic", seed=SEED, scale=max(SCALE * 0.1, 0.004))
    tr, te = ds.split(seed=SEED)
    a_tr, p_tr = vertical_split(tr, seed=SEED)
    a_te, p_te = vertical_split(te, seed=SEED)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=64, n_epochs=EPOCHS, w_a=4, w_p=4,
                    profile=prof, seed=SEED)
    sim = simulate(cfg)
    mk = lambda: VFLTrainer(cfg, a_tr, p_tr, a_te, p_te, ds.task,
                            seed=SEED)
    return cfg, sim, mk


def _timed(mk, sim, engine):
    trainer = mk()
    t0 = time.perf_counter()
    res = trainer.replay(sim, engine=engine, eval_every_epoch=False)
    return time.perf_counter() - t0, res


def run() -> None:
    cfg, sim, mk = _build()
    n_events = len(sim.events)

    _timed(mk, sim, "event")                     # warm per-op jit caches
    event_s, res_e = _timed(mk, sim, "event")
    emit("replay/event", event_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / event_s:.1f};total_s={event_s:.2f};"
         f"final={res_e.final_metric:.4f}")

    cold_s, _ = _timed(mk, sim, "compiled")      # schedule+trace+XLA
    comp_s, res_c = _timed(mk, sim, "compiled")  # steady state
    emit("replay/compiled_cold", cold_s / cfg.n_epochs * 1e6,
         f"one_time_compile_s={max(cold_s - comp_s, 0.0):.2f};"
         f"total_s={cold_s:.2f}")
    emit("replay/compiled", comp_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / comp_s:.1f};total_s={comp_s:.2f};"
         f"final={res_c.final_metric:.4f}")

    emit("replay/speedup", comp_s / cfg.n_epochs * 1e6,
         f"compiled_vs_event_x={event_s / comp_s:.2f};"
         f"cold_vs_event_x={event_s / cold_s:.2f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
