"""Replay-engine throughput: the segmented compiled engine vs. the packed
and legacy dense lane layouts vs. the per-event Python loop, on the
synthetic `pubsub` configuration (batch 256 — the paper's operating
regime, where per-event network compute dominates scheduling overhead).

Reports, per engine: steady-state wall-clock per epoch, replayed
events/sec, and (for the compiled engines) the schedule's executed-lane
occupancy — the fraction of vmapped lane slots doing real work, i.e.
the quantity the Pub/Sub design maximizes for worker utilization (see
docs/architecture.md).  For the compiled engines the one-time cost
(schedule compilation + jit trace + XLA compile) is measured separately
as `replay/segmented_cold`; with the persistent XLA cache
(`core.xla_cache`) it is paid once per machine.  Steady-state numbers
are the best of three replays, which hit the process-wide runner cache
— the regime any multi-run experiment actually sits in.  The event
engine is likewise measured after a warmup replay.

A second, per-tick **fixed-cost microbenchmark** sweeps B in {32, 256}
across the three compiled layouts.  At B=32 the per-tick math is ~8x
cheaper while the per-tick fixed overhead (lax.cond carry copies, ring
addressing, optimizer dispatch) is unchanged, so the per-tick time at
small batch isolates exactly the overhead the segmented cond-free
bodies remove; the sweep is emitted as `replay/micro_*` rows and the
`micro` record so the fixed-cost trajectory is tracked across PRs.

A third **sweep-reuse** section measures the Session API's
compile-once/run-many amortization: a 4-point same-shape seed sweep
(`api.run_sweep`) against a cold one-shot `run_experiment` of the same
config.  The cold point pays data prep + DES + schedule lowering + jit
tracing; warm points reuse the cached program and pay only model init +
the training scans.  Emitted as `replay/sweep_*` rows and the
`sweep_reuse` record so the amortization win is tracked across PRs.

A fourth **mesh replay** section measures the replica-sharded engine
(`Session(cfg, n_devices=n)`) across forced host device counts {1, 2, 4}
and B in {32, 256}: steady-state epoch wall clock, the schedule's
executed-lane occupancy (work-row based, so invariant under the lane
relabelling — it is reported to pin exactly that), and
the compiled collective counts of the epoch scan program and the
aggregation kernel (the design's "psum count" — aggregation is the only
*semantic* cross-device exchange; anything else is partitioner
plumbing).  Each point runs in a fresh subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
imports.  Emitted as `replay/mesh_*` rows and the `replay_mesh` record.

Emits the harness CSV on stdout plus a machine-readable
`BENCH_replay.json` in the working directory.

Scale knobs (env): REPRO_BENCH_SCALE (dataset fraction, default 0.05),
REPRO_BENCH_EPOCHS (default 5).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.core.trainer import VFLTrainer
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split

from benchmarks.common import EPOCHS, SCALE, SEED, emit

PACKS = ("dense", "packed", "segmented")

# worker shape of the point-stacked sweep record (same as the main
# section: at larger pools the 4-point carry outgrows this box's cache
# and the stacking win drowns in DRAM traffic — w=8/10 measured ~1.0x)
SWEEP_STACKED_WORKERS = (4, 4)

# mesh replay sweep: forced host device counts x batch sizes; 6 workers
# so every count exercises padded lanes (6-on-2 and 6-on-4 both pad)
MESH_DEVICES = (1, 2, 4)
MESH_BATCHES = (32, 256)
MESH_WORKERS = (6, 6)


def _build(method: str = "pubsub", batch_size: int = 256):
    ds = load("synthetic", seed=SEED, scale=max(SCALE * 0.4, 0.004))
    tr, te = ds.split(seed=SEED)
    a_tr, p_tr = vertical_split(tr, seed=SEED)
    a_te, p_te = vertical_split(te, seed=SEED)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=batch_size, n_epochs=EPOCHS, w_a=4, w_p=4,
                    profile=prof, seed=SEED)
    sim = simulate(cfg)
    mk = lambda: VFLTrainer(cfg, a_tr, p_tr, a_te, p_te, ds.task,
                            seed=SEED)
    return cfg, sim, mk


def _timed(mk, sim, engine, pack="segmented", **kw):
    trainer = mk()
    t0 = time.perf_counter()
    res = trainer.replay(sim, engine=engine, pack=pack,
                         eval_every_epoch=False, **kw)
    return time.perf_counter() - t0, res


def _steady(mk, sim, packs=PACKS, reps=3):
    """Best-of-`reps` warm replays per layout, interleaved so drifting
    machine load biases no layout."""
    best = {p: None for p in packs}
    res = {}
    for _ in range(reps):
        for pack in packs:
            t, r = _timed(mk, sim, "compiled", pack)
            res[pack] = r
            best[pack] = t if best[pack] is None else min(best[pack], t)
    return best, res


def _micro_row(B: int, best: dict, res: dict) -> dict:
    """Emit one batch size's micro rows.  us/tick at B=32 is dominated
    by per-tick fixed overhead (the per-tick math is ~8x cheaper while
    the fixed cost is unchanged), so the small-batch segmented-vs-packed
    wall-clock gap is the cond-removal payoff.  The speedup is reported
    on total seconds (identical replayed work per layout); the us/tick
    figures are per-layout observables — layouts may execute different
    tick counts, so their ratio alone would conflate fewer/wider ticks
    with lower per-tick overhead."""
    row = {}
    for pack in PACKS:
        r = res[pack]
        us_tick = best[pack] / max(r.n_ticks, 1) * 1e6
        emit(f"replay/micro_b{B}_{pack}", us_tick,
             f"total_s={best[pack]:.3f};n_ticks={r.n_ticks};"
             f"lane_occupancy={r.lane_occupancy:.3f}")
        row[pack] = {"total_s": best[pack], "us_per_tick": us_tick,
                     "n_ticks": r.n_ticks,
                     "lane_occupancy": r.lane_occupancy}
    row["segmented_vs_packed_x"] = (row["packed"]["total_s"] /
                                    row["segmented"]["total_s"])
    return row


def _drop_row(mk, sim, B: int, row: dict) -> None:
    """A/B the donation-aliased ``.at[].set(mode="drop")`` replica
    scatter against the default where-merge on the segmented layout
    (the ROADMAP "re-measure on accelerators" item, one command away:
    ``python -m benchmarks.replay_throughput``).  On CPU the where-merge
    is expected to stay ahead — the scatter serializes — so the default
    is unchanged; on accelerators the drop-scatter can alias the donated
    carry in place."""
    _timed(mk, sim, "compiled", "segmented", scatter_drop=True)  # warm
    t1, r1 = _timed(mk, sim, "compiled", "segmented", scatter_drop=True)
    t2, _ = _timed(mk, sim, "compiled", "segmented", scatter_drop=True)
    t = min(t1, t2)
    us_tick = t / max(r1.n_ticks, 1) * 1e6
    vs_where = row["segmented"]["total_s"] / t
    emit(f"replay/micro_b{B}_segmented_drop", us_tick,
         f"total_s={t:.3f};n_ticks={r1.n_ticks};"
         f"drop_vs_where_x={vs_where:.2f}")
    row["segmented_drop"] = {"total_s": t, "us_per_tick": us_tick,
                             "n_ticks": r1.n_ticks,
                             "drop_vs_where_x": vs_where}


def _micro(record: dict, best_256: dict, res_256: dict,
           mk_256, sim_256) -> None:
    """Per-tick fixed-cost sweep: B in {32, 256} x the three layouts,
    plus the segmented drop-scatter variant at each B.
    The B=256 point reuses the steady measurements of the main section
    (same config, just measured); only B=32 is built and timed here."""
    record["micro"] = {"B256": _micro_row(256, best_256, res_256)}
    _drop_row(mk_256, sim_256, 256, record["micro"]["B256"])
    cfg, sim, mk = _build(batch_size=32)
    for pack in PACKS:
        _timed(mk, sim, "compiled", pack)            # warm
    best, res = _steady(mk, sim, reps=2)
    record["micro"]["B32"] = _micro_row(32, best, res)
    _drop_row(mk, sim, 32, record["micro"]["B32"])


def _sweep_reuse(record: dict) -> None:
    """Compile-once/run-many amortization: 4 same-shape seed points via
    `run_sweep` (one compile, three cache hits) vs a COLD one-shot
    `run_experiment` on a fresh seed (the per-point price before the
    Session API).  Warm points skip the DES, schedule lowering and jit
    tracing but — because the sweep varies the data seed — still pay
    model init AND per-seed data prep, so `warm_point_s_mean` is an
    upper bound on the irreducible per-point cost (an lr/dp_mu sweep at
    fixed seed also shares the prepared data)."""
    from repro.api import (ExperimentConfig, reset_compile_cache,
                           run_sweep)
    from repro.core.runtime import run_experiment

    # B=128: a shape the main/micro sections never touch, so the sweep's
    # cold point genuinely pays schedule lowering + jit tracing
    mk_cfg = lambda s: ExperimentConfig(
        method="pubsub", dataset="synthetic",
        scale=max(SCALE * 0.4, 0.004), n_epochs=EPOCHS, batch_size=128,
        w_a=4, w_p=4, seed=s)
    reset_compile_cache()
    sw = run_sweep([mk_cfg(s) for s in range(4)])
    # cold monolith reference AFTER the sweep: reuse="exact" ignores the
    # structural cache, so seed 99 pays the full per-point pipeline
    t0 = time.perf_counter()
    run_experiment(mk_cfg(99))
    cold_monolith_s = time.perf_counter() - t0

    s = sw.stats
    warm, cold = s["warm_wall_s_mean"], s["cold_wall_s_mean"]
    record["sweep_reuse"] = {
        "n_points": s["n_points"], "compiles": s["compiles"],
        "cache_hits": s["cache_hits"],
        "cold_point_s": cold, "warm_point_s_mean": warm,
        "cold_run_experiment_s": cold_monolith_s,
        "warm_vs_cold_x": cold / max(warm, 1e-9),
        "warm_vs_run_experiment_x": cold_monolith_s / max(warm, 1e-9),
        "point_wall_s": s["point_wall_s"],
    }
    emit("replay/sweep_warm_point", warm * 1e6,
         f"warm_vs_cold_x={cold / max(warm, 1e-9):.2f};"
         f"warm_vs_run_experiment_x="
         f"{cold_monolith_s / max(warm, 1e-9):.2f};"
         f"compiles={s['compiles']};cache_hits={s['cache_hits']}")
    emit("replay/sweep_cold_point", cold * 1e6,
         f"run_experiment_s={cold_monolith_s:.2f};"
         f"sweep_cold_s={cold:.2f}")


def _sweep_stacked(record: dict) -> None:
    """Point-stacked vs sequential sweep execution: the same 4-point
    same-shape seed sweep (B=256, the paper's operating regime) run warm
    both ways.  Sequential warm points pay the full per-point epoch
    dispatch + per-tick program N times; the stacked sweep fuses the
    group into ONE vmapped device program (`run_sweep(stacked=True)`),
    so the per-tick fixed costs are paid once and the batched math
    amortizes XLA-CPU's small-op inefficiency.  Both directions measure
    `run_sweep` wall clock with eval off (eval cost is identical per
    point in both modes and only dilutes the ratio).  Emitted as the
    `sweep_stacked` record + `replay/sweep_stacked` row."""
    from repro.api import (ExperimentConfig, compile_stats,
                           reset_compile_cache, run_sweep)

    mk_cfg = lambda s: ExperimentConfig(
        method="pubsub", dataset="synthetic",
        scale=max(SCALE * 0.4, 0.004), n_epochs=EPOCHS, batch_size=256,
        w_a=SWEEP_STACKED_WORKERS[0], w_p=SWEEP_STACKED_WORKERS[1],
        seed=s)
    cfgs = [mk_cfg(s) for s in range(4)]
    reset_compile_cache()
    run_sweep(cfgs, eval_every_epoch=False)          # compile + warm seq
    before = compile_stats()
    t0 = time.perf_counter()
    st = run_sweep(cfgs, stacked=True, stack_chunk=4,
                   eval_every_epoch=False)
    stacked_cold_s = time.perf_counter() - t0        # + the vmap trace
    # both modes warm; interleave best-of-3 so drifting machine load
    # biases neither (the same protocol as `_steady`).  Two stacked
    # strategies are tracked: the platform default (per-point chunks on
    # CPU) and the whole-group single vmapped program (the accelerator
    # default, forced here with stack_chunk=4).
    seq_s = stacked_s = one_prog_s = None
    for _ in range(3):
        t0 = time.perf_counter()
        seq = run_sweep(cfgs, eval_every_epoch=False)
        dt = time.perf_counter() - t0
        seq_s = dt if seq_s is None else min(seq_s, dt)
        t0 = time.perf_counter()
        st = run_sweep(cfgs, stacked=True, eval_every_epoch=False)
        dt = time.perf_counter() - t0
        stacked_s = dt if stacked_s is None else min(stacked_s, dt)
        t0 = time.perf_counter()
        op = run_sweep(cfgs, stacked=True, stack_chunk=4,
                       eval_every_epoch=False)
        dt = time.perf_counter() - t0
        one_prog_s = dt if one_prog_s is None else min(one_prog_s, dt)
    compiles = compile_stats()["compiles"] - before["compiles"]
    assert compiles == 0, "stacked sweep must reuse the cached program"
    for a, b, c in zip(seq, st, op):
        assert a.train.history == b.train.history == c.train.history, \
            "stacked point diverged from sequential"
    speedup = seq_s / stacked_s
    record["sweep_stacked"] = {
        "n_points": 4, "batch_size": 256,
        "w_a": SWEEP_STACKED_WORKERS[0], "w_p": SWEEP_STACKED_WORKERS[1],
        "sequential_warm_s": seq_s, "stacked_warm_s": stacked_s,
        "stacked_one_program_warm_s": one_prog_s,
        "stacked_cold_s": stacked_cold_s,
        "stacked_vs_sequential_x": speedup,
        "one_program_vs_sequential_x": seq_s / one_prog_s,
        "compiles_during_stacked": compiles,
        "points_per_group": st.stats["points_per_group"],
        "stacked_groups": st.stats["stacked_groups"],
    }
    emit("replay/sweep_stacked", stacked_s * 1e6,
         f"stacked_vs_sequential_x={speedup:.2f};"
         f"one_program_vs_sequential_x={seq_s / one_prog_s:.2f};"
         f"sequential_warm_s={seq_s:.2f};stacked_warm_s={stacked_s:.2f};"
         f"stacked_groups={st.stats['stacked_groups']}")


def _mesh_point(payload: dict) -> dict:
    """Worker body for one (device_count, B) mesh measurement.  Runs in
    a fresh process whose XLA_FLAGS already force the device count (the
    flag must precede the jax import, so `_mesh` re-invokes this module
    per point instead of looping in-process)."""
    from repro.api import ExperimentConfig, Session
    from repro.core import jit_pipeline as jp
    from repro.core import mesh_replay

    n, B = payload["n_devices"], payload["B"]
    cfg = ExperimentConfig(
        method="pubsub", dataset="synthetic",
        scale=max(SCALE * 0.4, 0.004), n_epochs=EPOCHS, batch_size=B,
        w_a=MESH_WORKERS[0], w_p=MESH_WORKERS[1], seed=SEED)
    sess = Session(cfg, n_devices=n)
    t0 = time.perf_counter()
    sess.run(eval_every_epoch=False)             # compile + cold epochs
    cold_s = time.perf_counter() - t0
    best = None
    for _ in range(2):                           # warm: cached program
        t0 = time.perf_counter()
        sess.run(eval_every_epoch=False)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)

    # collective counts from the compiled HLO of (a) epoch 0's scan
    # program and (b) the aggregation kernel — the latter is the only
    # semantic cross-device exchange in the design
    eng = sess.compile().engine
    trainer = sess._make_trainer(*sess._resolve_point(None, None, None))
    data = eng.stage_data(trainer.Xa, trainer.Xp, trainer.y)
    st = eng.init_state(trainer.theta_a, trainer.opt_a, trainer.theta_p,
                        trainer.opt_p, trainer.d_emb, seed=SEED)
    carry = jp.TrainerState(*st).carry
    ta, _, tp, _ = carry[0], carry[1], carry[2], carry[3]
    runner = jp._get_segmented_runner(eng.spec, eng._opt_builder,
                                      eng._opt_key, eng._structures[0])
    scan_hlo = runner.lower(carry, eng._seg_xs[0], data,
                            eng.hyper).compile().as_text()
    agg_hlo = eng._agg_both.lower(ta, tp).compile().as_text()
    return {"n_devices": n, "B": B, "epoch_s": best / EPOCHS,
            "cold_s": cold_s, "occupancy": eng.schedule.lane_occupancy(),
            "scan_collectives": mesh_replay.count_collectives(scan_hlo),
            "agg_collectives": mesh_replay.count_collectives(agg_hlo)}


def _mesh(record: dict) -> None:
    """Mesh-replay sweep: devices x batch sizes, one subprocess each."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for n in MESH_DEVICES:
        for B in MESH_BATCHES:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_"
                                f"count={n}")
            env.setdefault("JAX_PLATFORMS", "cpu")
            env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep +
                                 env.get("PYTHONPATH", ""))
            proc = subprocess.run(
                [sys.executable, "-m", "benchmarks.replay_throughput",
                 "--mesh-point",
                 json.dumps({"n_devices": n, "B": B})],
                capture_output=True, text=True, env=env, cwd=root,
                timeout=3600)
            if proc.returncode != 0:
                raise RuntimeError(
                    f"mesh point d{n} b{B} failed:\n{proc.stdout}\n"
                    f"{proc.stderr}")
            line = [l for l in proc.stdout.splitlines()
                    if l.startswith("MESH:")][-1]
            rows.append(json.loads(line[len("MESH:"):]))
    base = {r["B"]: r["epoch_s"] for r in rows if r["n_devices"] == 1}
    for r in rows:
        r["vs_1dev_x"] = base[r["B"]] / r["epoch_s"]
        agg_ar = r["agg_collectives"]["all-reduce"]
        emit(f"replay/mesh_d{r['n_devices']}_b{r['B']}",
             r["epoch_s"] * 1e6,
             f"vs_1dev_x={r['vs_1dev_x']:.2f};"
             f"occupancy={r['occupancy']:.3f};"
             f"agg_all_reduce={agg_ar};"
             f"scan_all_reduce={r['scan_collectives']['all-reduce']}")
    record["replay_mesh"] = {
        "method": "pubsub", "pack": "segmented",
        "w_a": MESH_WORKERS[0], "w_p": MESH_WORKERS[1],
        "n_epochs": EPOCHS, "rows": rows}


def run() -> None:
    cfg, sim, mk = _build()
    n_events = len(sim.events)
    record = {"config": {"method": cfg.method, "batch_size": cfg.batch_size,
                         "n_epochs": cfg.n_epochs, "w_a": cfg.w_a,
                         "w_p": cfg.w_p, "n_events": n_events}}

    _timed(mk, sim, "event")                     # warm per-op jit caches
    event_s, res_e = _timed(mk, sim, "event")
    emit("replay/event", event_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / event_s:.1f};total_s={event_s:.2f};"
         f"final={res_e.final_metric:.4f}")
    record["event"] = {"total_s": event_s, "final": res_e.final_metric}

    cold_s, _ = _timed(mk, sim, "compiled", "segmented")  # sched+trace+XLA
    for pack in ("dense", "packed"):             # warm the baselines too
        _timed(mk, sim, "compiled", pack)
    best, res = _steady(mk, sim)
    for pack in PACKS:
        t, r = best[pack], res[pack]
        emit(f"replay/{pack}", t / cfg.n_epochs * 1e6,
             f"events_per_s={n_events / t:.1f};total_s={t:.2f};"
             f"lane_occupancy={r.lane_occupancy:.3f};"
             f"n_ticks={r.n_ticks};final={r.final_metric:.4f}")
        record[pack] = {"total_s": t, "final": r.final_metric,
                        "lane_occupancy": r.lane_occupancy,
                        "n_ticks": r.n_ticks}
    seg_s = best["segmented"]
    record["segmented"]["cold_s"] = cold_s
    emit("replay/segmented_cold", cold_s / cfg.n_epochs * 1e6,
         f"one_time_compile_s={max(cold_s - seg_s, 0.0):.2f};"
         f"total_s={cold_s:.2f}")

    emit("replay/speedup", seg_s / cfg.n_epochs * 1e6,
         f"segmented_vs_packed_x={best['packed'] / seg_s:.2f};"
         f"segmented_vs_dense_x={best['dense'] / seg_s:.2f};"
         f"segmented_vs_event_x={event_s / seg_s:.2f};"
         f"occupancy_segmented={res['segmented'].lane_occupancy:.3f};"
         f"occupancy_packed={res['packed'].lane_occupancy:.3f}")
    record["speedup"] = {
        "segmented_vs_packed": best["packed"] / seg_s,
        "segmented_vs_dense": best["dense"] / seg_s,
        "segmented_vs_event": event_s / seg_s,
        "packed_vs_dense": best["dense"] / best["packed"],
    }

    _micro(record, best, res, mk, sim)
    _sweep_reuse(record)
    _sweep_stacked(record)
    _mesh(record)

    with open("BENCH_replay.json", "w") as fh:
        json.dump(record, fh, indent=2)


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--mesh-point":
        print("MESH:" + json.dumps(_mesh_point(json.loads(sys.argv[2]))))
        sys.exit(0)
    from benchmarks.common import emit_header
    emit_header()
    run()
