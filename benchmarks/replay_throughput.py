"""Replay-engine throughput: the packed compiled engine vs. the legacy
dense lane layout vs. the per-event Python loop, on the synthetic
`pubsub` configuration (batch 256 — the paper's operating regime, where
per-event network compute dominates scheduling overhead).

Reports, per engine: steady-state wall-clock per epoch, replayed
events/sec, and (for the compiled engines) the schedule's executed-lane
occupancy — the fraction of vmapped lane slots doing real work, i.e.
the quantity the Pub/Sub design maximizes for worker utilization (see
docs/architecture.md).  For the compiled engines the one-time cost
(schedule compilation + jit trace + XLA compile) is measured separately
as `replay/packed_cold`; with the persistent XLA cache
(`core.xla_cache`) it is paid once per machine.  Steady-state numbers
are the best of three replays, which hit the process-wide runner cache
— the regime any multi-run experiment actually sits in.  The event
engine is likewise measured after a warmup replay.

Emits the harness CSV on stdout plus a machine-readable
`BENCH_replay.json` in the working directory.

Scale knobs (env): REPRO_BENCH_SCALE (dataset fraction, default 0.05),
REPRO_BENCH_EPOCHS (default 5).
"""
from __future__ import annotations

import json
import time

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.core.trainer import VFLTrainer
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split

from benchmarks.common import EPOCHS, SCALE, SEED, emit


def _build(method: str = "pubsub"):
    ds = load("synthetic", seed=SEED, scale=max(SCALE * 0.4, 0.004))
    tr, te = ds.split(seed=SEED)
    a_tr, p_tr = vertical_split(tr, seed=SEED)
    a_te, p_te = vertical_split(te, seed=SEED)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=256, n_epochs=EPOCHS, w_a=4, w_p=4,
                    profile=prof, seed=SEED)
    sim = simulate(cfg)
    mk = lambda: VFLTrainer(cfg, a_tr, p_tr, a_te, p_te, ds.task,
                            seed=SEED)
    return cfg, sim, mk


def _timed(mk, sim, engine, pack="packed"):
    trainer = mk()
    t0 = time.perf_counter()
    res = trainer.replay(sim, engine=engine, pack=pack,
                         eval_every_epoch=False)
    return time.perf_counter() - t0, res


def _steady_pair(mk, sim, reps=3):
    """Best-of-`reps` warm replays for the dense and packed layouts,
    interleaved so drifting machine load biases neither side."""
    best = {"dense": None, "packed": None}
    res = {}
    for _ in range(reps):
        for pack in ("dense", "packed"):
            t, r = _timed(mk, sim, "compiled", pack)
            res[pack] = r
            best[pack] = t if best[pack] is None else min(best[pack], t)
    return best, res


def run() -> None:
    cfg, sim, mk = _build()
    n_events = len(sim.events)
    record = {"config": {"method": cfg.method, "batch_size": cfg.batch_size,
                         "n_epochs": cfg.n_epochs, "w_a": cfg.w_a,
                         "w_p": cfg.w_p, "n_events": n_events}}

    _timed(mk, sim, "event")                     # warm per-op jit caches
    event_s, res_e = _timed(mk, sim, "event")
    emit("replay/event", event_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / event_s:.1f};total_s={event_s:.2f};"
         f"final={res_e.final_metric:.4f}")
    record["event"] = {"total_s": event_s, "final": res_e.final_metric}

    cold_s, _ = _timed(mk, sim, "compiled", "packed")   # sched+trace+XLA
    _timed(mk, sim, "compiled", "dense")                # warm dense too
    best, res = _steady_pair(mk, sim)
    dense_s, res_d = best["dense"], res["dense"]
    packed_s, res_p = best["packed"], res["packed"]
    emit("replay/dense", dense_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / dense_s:.1f};total_s={dense_s:.2f};"
         f"lane_occupancy={res_d.lane_occupancy:.3f};"
         f"n_ticks={res_d.n_ticks}")
    record["dense"] = {"total_s": dense_s, "final": res_d.final_metric,
                       "lane_occupancy": res_d.lane_occupancy,
                       "n_ticks": res_d.n_ticks}
    emit("replay/packed_cold", cold_s / cfg.n_epochs * 1e6,
         f"one_time_compile_s={max(cold_s - packed_s, 0.0):.2f};"
         f"total_s={cold_s:.2f}")
    emit("replay/packed", packed_s / cfg.n_epochs * 1e6,
         f"events_per_s={n_events / packed_s:.1f};total_s={packed_s:.2f};"
         f"lane_occupancy={res_p.lane_occupancy:.3f};"
         f"n_ticks={res_p.n_ticks};final={res_p.final_metric:.4f}")
    record["packed"] = {"total_s": packed_s, "cold_s": cold_s,
                        "final": res_p.final_metric,
                        "lane_occupancy": res_p.lane_occupancy,
                        "n_ticks": res_p.n_ticks}

    emit("replay/speedup", packed_s / cfg.n_epochs * 1e6,
         f"packed_vs_dense_x={dense_s / packed_s:.2f};"
         f"packed_vs_event_x={event_s / packed_s:.2f};"
         f"occupancy_packed={res_p.lane_occupancy:.3f};"
         f"occupancy_dense={res_d.lane_occupancy:.3f}")
    record["speedup"] = {"packed_vs_dense": dense_s / packed_s,
                         "packed_vs_event": event_s / packed_s}

    with open("BENCH_replay.json", "w") as fh:
        json.dump(record, fh, indent=2)


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
