"""Generate the EXPERIMENTS.md tables: §Dry-run / §Roofline from
runs/dryrun.jsonl, §Serving from BENCH_serve.json, and §Faults from
BENCH_fault.json (each section renders only when its record exists).

Usage:
    PYTHONPATH=src python -m benchmarks.report [runs/dryrun.jsonl]
"""
from __future__ import annotations

import json
import os
import sys

from repro.configs import SHAPES
from benchmarks.roofline import load_records, roofline_terms


def gb(x):
    return f"{x / 1e9:.2f}"


def dryrun_tables(path: str) -> None:
    recs = load_records(path)
    by_mesh = {"single": [], "multi": []}
    for r in recs:
        by_mesh.setdefault(r["mesh"], []).append(r)

    print("### §Dry-run — lower+compile status "
          "(per-device memory_analysis)\n")
    print("| arch | shape | mesh | status | args GB/dev | peak GB/dev | "
          "compile s | note |")
    print("|---|---|---|---|---|---|---|---|")
    for mesh in ("single", "multi"):
        for r in sorted(by_mesh[mesh], key=lambda x: (x["arch"],
                                                      x["shape"])):
            mem = r.get("memory", {})
            args = mem.get("argument_size_in_bytes", 0)
            peak = mem.get("peak_memory_in_bytes", 0)
            print(f"| {r['arch']} | {r['shape']} | {mesh} | "
                  f"{r.get('status')} | {gb(args)} | {gb(peak)} | "
                  f"{r.get('compile_s', '')} | {r.get('note', '')} |")

    print("\n### §Roofline — three terms per (arch x shape), single-pod "
          "(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | bound s | MODEL_FLOPS/dev | useful ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(by_mesh["single"], key=lambda x: (x["arch"],
                                                      x["shape"])):
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                  f" — | — | {r.get('note', '')} |")
            continue
        t = roofline_terms(r)
        if t is None:
            continue
        print(f"| {r['arch']} | {r['shape']} | {t['compute']:.3e} | "
              f"{t['memory']:.3e} | {t['collective']:.3e} | "
              f"{t['dominant']} | {t['bound_s']:.3e} | "
              f"{t['model_flops']:.3e} | {t['useful_ratio']:.2f} |")


def serve_chaos_table(rec: dict) -> None:
    cfg = rec["config"]
    print(f"\n### §Serving under chaos — deadlines, admission control, "
          f"crash recovery ({cfg['arch']}, qps={cfg['qps']:g}, "
          f"queue_cap={cfg['queue_cap']}, "
          f"deadline={cfg['deadline_s']:g}s)\n")
    print("| severity | goodput | shed rate | restarts | "
          "recovery p50 ms | replay parity | finish reasons |")
    print("|---|---|---|---|---|---|---|")
    for name, row in rec["severities"].items():
        p = row["replay_parity"]
        reasons = ", ".join(f"{k}:{v}" for k, v in
                            sorted(row["by_finish_reason"].items()))
        print(f"| {name} | {row['goodput']:.2f} | "
              f"{row['shed_rate']:.2f} | {row['restarts']} | "
              f"{row['recovery_p50_ms']:.1f} | "
              f"{p['matched']}/{p['checked']} | {reasons} |")


def serve_table(path: str = "BENCH_serve.json") -> None:
    with open(path) as fh:
        rec = json.load(fh)
    if "chaos" in rec:
        serve_chaos_table(rec["chaos"])
    if "config" not in rec:          # chaos-only record: nothing else
        return
    cfg = rec["config"]
    print(f"\n### §Serving — continuous batching under Poisson load "
          f"(slots={cfg['slots']}, gen={cfg['gen']}, "
          f"n={cfg['n_requests']} requests)\n")
    print("| arch | offered qps | tok/s | x serial | ttft p50/p99 ms | "
          "itl p50/p99 ms | occupancy |")
    print("|---|---|---|---|---|---|---|")
    for arch, rows in rec["archs"].items():
        s = rows["serial"]
        print(f"| {arch} | serial | {s['tokens_per_s']:.1f} | 1.00 | "
              f"— | — | — |")
        for p in rows["points"]:
            print(f"| {arch} | {p['offered_qps']:g} | "
                  f"{p['tokens_per_s']:.1f} | "
                  f"{p['speedup_vs_serial']:.2f} | "
                  f"{p['ttft_p50_ms']:.1f}/{p['ttft_p99_ms']:.1f} | "
                  f"{p['itl_p50_ms']:.1f}/{p['itl_p99_ms']:.1f} | "
                  f"{p['occupancy']:.2f} |")


def fault_table(path: str = "BENCH_fault.json") -> None:
    with open(path) as fh:
        rec = json.load(fh)
    print("\n### §Faults — accuracy + wall-clock degradation vs each "
          "method's healthy run\n")
    print("| method | severity | final | acc drop | slowdown | "
          "staleness |")
    print("|---|---|---|---|---|---|")
    for method in ("pubsub", "vfl_ps"):
        rows = rec.get(method, {})
        for sev, row in rows.items():
            if sev == "healthy":
                print(f"| {method} | healthy | {row['final']:.4f} | — | "
                      f"1.00 | — |")
                continue
            print(f"| {method} | {sev} | {row['final']:.4f} | "
                  f"{row['acc_drop']:+.4f} | {row['slowdown']:.2f}x | "
                  f"{row.get('staleness', 0):.2f} |")
    p = rec.get("planner_under_straggler")
    if p:
        print(f"\nPlanner under severe straggler: acc drop "
              f"{p['acc_drop']:+.4f}, slowdown {p['slowdown']:.2f}x "
              f"({p['n_stragglers_p']} passive stragglers).")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.jsonl"
    emitted = False
    if os.path.exists(path):
        dryrun_tables(path)
        emitted = True
    for render, bench in ((serve_table, "BENCH_serve.json"),
                          (fault_table, "BENCH_fault.json")):
        if os.path.exists(bench):
            render(bench)
            emitted = True
    if not emitted:
        print("# nothing to report: no runs/dryrun.jsonl, "
              "BENCH_serve.json, or BENCH_fault.json", file=sys.stderr)


if __name__ == "__main__":
    main()
