"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
runs/dryrun.jsonl.  Usage:
    PYTHONPATH=src python -m benchmarks.report [runs/dryrun.jsonl]
"""
from __future__ import annotations

import json
import sys

from repro.configs import SHAPES
from benchmarks.roofline import load_records, roofline_terms


def gb(x):
    return f"{x / 1e9:.2f}"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.jsonl"
    recs = load_records(path)
    by_mesh = {"single": [], "multi": []}
    for r in recs:
        by_mesh.setdefault(r["mesh"], []).append(r)

    print("### §Dry-run — lower+compile status "
          "(per-device memory_analysis)\n")
    print("| arch | shape | mesh | status | args GB/dev | peak GB/dev | "
          "compile s | note |")
    print("|---|---|---|---|---|---|---|---|")
    for mesh in ("single", "multi"):
        for r in sorted(by_mesh[mesh], key=lambda x: (x["arch"],
                                                      x["shape"])):
            mem = r.get("memory", {})
            args = mem.get("argument_size_in_bytes", 0)
            peak = mem.get("peak_memory_in_bytes", 0)
            print(f"| {r['arch']} | {r['shape']} | {mesh} | "
                  f"{r.get('status')} | {gb(args)} | {gb(peak)} | "
                  f"{r.get('compile_s', '')} | {r.get('note', '')} |")

    print("\n### §Roofline — three terms per (arch x shape), single-pod "
          "(v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | bound s | MODEL_FLOPS/dev | useful ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(by_mesh["single"], key=lambda x: (x["arch"],
                                                      x["shape"])):
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped |"
                  f" — | — | {r.get('note', '')} |")
            continue
        t = roofline_terms(r)
        if t is None:
            continue
        print(f"| {r['arch']} | {r['shape']} | {t['compute']:.3e} | "
              f"{t['memory']:.3e} | {t['collective']:.3e} | "
              f"{t['dominant']} | {t['bound_s']:.3e} | "
              f"{t['model_flops']:.3e} | {t['useful_ratio']:.2f} |")


if __name__ == "__main__":
    main()
