"""Paper Fig. 3: computation & communication efficiency on the synthetic
dataset — running time to target accuracy, CPU utilization, waiting time,
and communication cost, per method (B=256, w_a=8, w_p=10)."""
from __future__ import annotations

from repro.api import ExperimentConfig
from repro.core.runtime import time_to_target

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")
TARGET_AUC = 0.91            # the paper's target accuracy (91%)


def run() -> None:
    results = {}
    for m in METHODS:
        r = run_point(ExperimentConfig(
            method=m, dataset="synthetic", scale=max(SCALE * 0.1, 0.002),
            n_epochs=EPOCHS, batch_size=256, w_a=8, w_p=10, seed=SEED))
        results[m] = r
        ttt = time_to_target(r, TARGET_AUC)
        emit(f"fig3/time/{m}", r["sim_s_per_epoch"] * 1e6,
             f"sim_s={r['sim_s']:.3f};to_{TARGET_AUC}auc={ttt:.3f}s")
        emit(f"fig3/util/{m}", r["sim_s_per_epoch"] * 1e6,
             f"cpu_util={r['cpu_util'] * 100:.2f}%")
        emit(f"fig3/wait/{m}", r["sim_s_per_epoch"] * 1e6,
             f"waiting_per_epoch={r['waiting_per_epoch']:.4f}s")
        emit(f"fig3/comm/{m}", r["sim_s_per_epoch"] * 1e6,
             f"comm_mb={r['comm_mb']:.2f}")
    speedup = results["vfl"]["sim_s"] / results["pubsub"]["sim_s"]
    best_base = min(results[m]["sim_s"] for m in METHODS if m != "pubsub")
    emit("fig3/speedup", 0.0,
         f"vs_vfl={speedup:.2f}x;vs_best_baseline="
         f"{best_base / results['pubsub']['sim_s']:.2f}x")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
