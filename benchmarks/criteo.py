"""Paper Table 9 (Appendix H): scalability on the Criteo-style dataset.

The paper uses Criteo 1TB (4.5B samples); no network access here, so the
generator mirrors its shape (39 features, sparse-ish, noisy labels) at
REPRO_BENCH_SCALE x 4.5M samples (a further /1000 of the paper's run,
flagged in the row name).  Metrics mirror Table 9: AUC, runtime,
utilization, waiting, comm.
"""
from __future__ import annotations

from repro.api import ExperimentConfig

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")


def run() -> None:
    scale = max(SCALE * 0.01, 5e-4)           # criteo is 4.5B rows
    for m in METHODS:
        r = run_point(ExperimentConfig(
            method=m, dataset="criteo", scale=scale, n_epochs=EPOCHS,
            batch_size=64, w_a=8, w_p=10, seed=SEED))
        emit(f"table9/criteo/{m}", r["sim_s_per_epoch"] * 1e6,
             f"auc={r['final']:.4f};sim_s={r['sim_s']:.2f};"
             f"util={r['cpu_util']*100:.1f}%;"
             f"wait={r['waiting_per_epoch']:.3f};comm_mb={r['comm_mb']:.1f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
