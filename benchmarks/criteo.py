"""Paper Table 9 (Appendix H): scalability on the Criteo-style dataset.

The paper uses Criteo 1TB (4.5B samples); no network access here, so the
generator mirrors its shape (39 features, sparse-ish, noisy labels) at
REPRO_BENCH_SCALE x 4.5M samples (a further /1000 of the paper's run,
flagged in the row name).  Metrics mirror Table 9: AUC, runtime,
utilization, waiting, comm; every row also reports the process peak RSS.

A second **data-path** section runs one pubsub point at the full
4.5M-row target (REPRO_CRITEO_ROWS overrides) through the streaming
pipeline — chunked-PSI alignment, on-disk per-party feature shards,
windowed double-buffered staging — under a host-RAM budget
(REPRO_CRITEO_BUDGET_MB, default 256) that the resident data path could
not meet: resident `stage_data` materializes + device-puts the full
train block at once.  It emits `table9/criteo/data_path` and merges a
`data_path` record (rows/s, window size, staged-bytes high-water mark,
peak RSS) into `BENCH_replay.json`, plus a `stream_overhead` sub-record
measuring streaming-vs-resident warm wall clock on the B=256 synthetic
config where both fit in RAM (the ISSUE 6 >=0.9x criterion).
"""
from __future__ import annotations

import json
import os
import time

from repro.api import ExperimentConfig, Session

from benchmarks.common import (EPOCHS, SCALE, SEED, emit, peak_host_mb,
                               run_point)

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")

CRITEO_BASE_ROWS = 4_500_000
DATA_PATH_ROWS = int(os.environ.get("REPRO_CRITEO_ROWS",
                                    str(CRITEO_BASE_ROWS)))
DATA_BUDGET_MB = float(os.environ.get("REPRO_CRITEO_BUDGET_MB", "256"))


def _merge_bench_record(key: str, value: dict) -> None:
    """Insert `key` into BENCH_replay.json, preserving the replay
    benchmark's records if the file exists."""
    record = {}
    if os.path.exists("BENCH_replay.json"):
        with open("BENCH_replay.json") as fh:
            record = json.load(fh)
    record[key] = value
    with open("BENCH_replay.json", "w") as fh:
        json.dump(record, fh, indent=2)


def _stream_overhead() -> dict:
    """Warm streaming-vs-resident epoch throughput on the B=256
    synthetic config (the replay benchmark's operating regime) where
    both paths fit in RAM, at the default window size.  Measured at the
    engine level — warm `run_epoch` loops over pre-staged data, best of
    5, interleaved — so the identical per-run trainer/eval costs don't
    dilute the ratio; final states must stay bit-identical.  Streaming
    re-gathers and re-stages every window each epoch (that is the
    point), so this ratio IS the staging overhead double-buffering must
    hide; expected >=0.9x."""
    import jax
    import numpy as np

    from repro.data.shards import ArrayFeatures

    base = dict(method="pubsub", dataset="synthetic",
                scale=max(SCALE * 0.4, 0.004), n_epochs=EPOCHS,
                batch_size=256, w_a=4, w_p=4, seed=SEED)
    sess = Session(ExperimentConfig(**base))
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    hy = t.hyper()
    data = {"resident": eng.stage_data(t.Xa, t.Xp, t.y),
            "streaming": eng.stage_data(ArrayFeatures(np.asarray(t.Xa)),
                                        ArrayFeatures(np.asarray(t.Xp)),
                                        t.y, window_batches=32)}
    st0 = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                         t.d_emb, seed=SEED)
    n_epochs = base["n_epochs"]

    def epochs(d):
        st = st0
        for e in range(n_epochs):
            st = eng.run_epoch(st, e, d, hy)
        jax.block_until_ready(jax.tree.leaves(st.carry)[0])
        return st

    finals = {k: epochs(d) for k, d in data.items()}       # compile+warm
    for a, b in zip(jax.tree.leaves(finals["resident"].carry),
                    jax.tree.leaves(finals["streaming"].carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    best = {}
    for _ in range(5):                  # interleaved best-of-5 (vs drift)
        for label, d in data.items():
            t0 = time.perf_counter()
            epochs(d)
            dt = time.perf_counter() - t0
            best[label] = min(best.get(label, dt), dt)
    ratio = best["resident"] / best["streaming"]
    emit("table9/criteo/stream_overhead", best["streaming"] * 1e6,
         f"stream_vs_resident_x={ratio:.3f};"
         f"resident_s={best['resident']:.2f};"
         f"streaming_s={best['streaming']:.2f}")
    return {"batch_size": 256, "n_epochs": n_epochs,
            "resident_warm_s": best["resident"],
            "streaming_warm_s": best["streaming"],
            "stream_vs_resident_x": ratio,
            "windows_per_epoch":
                data["streaming"].stats["windows_per_epoch"][:n_epochs]}


def data_path() -> None:
    """The 4.5M-row Table 9 row through the streaming data path."""
    scale = DATA_PATH_ROWS / CRITEO_BASE_ROWS
    cfg = ExperimentConfig(
        method="pubsub", dataset="criteo", scale=scale, n_epochs=1,
        batch_size=4096, depth=3, w_a=4, w_p=4, seed=SEED,
        stream=True, stream_backing="shards",
        data_budget_mb=DATA_BUDGET_MB)
    sess = Session(cfg)
    t0 = time.perf_counter()
    prep = sess.prepare()          # chunked generate + shard + PSI-align
    prep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = sess.run(eval_every_epoch=False)
    train_s = time.perf_counter() - t0
    stats = dict(r.data_path)
    n, d = prep.n_samples, prep.d_a + prep.d_p
    rows_per_s = stats["rows_staged"] / max(stats["epoch_s"], 1e-9)
    resident_mb = n * (d + 1) * 4 / 1e6   # what stage_data would stage
    record = {
        "rows_total": DATA_PATH_ROWS, "rows_train": n, "d": d,
        "batch_size": cfg.batch_size, "depth": cfg.depth,
        "budget_mb": DATA_BUDGET_MB,
        "resident_train_block_mb": resident_mb,
        "window_batches": stats["window_batches"],
        "windows_per_epoch": stats["windows_per_epoch"],
        "peak_staged_mb": stats["peak_staged_bytes"] / 1e6,
        "rows_per_s": rows_per_s,
        "stage_s": stats["stage_s"], "epoch_s": stats["epoch_s"],
        "prep_s": prep_s, "train_wall_s": train_s,
        "peak_host_rss_mb": peak_host_mb(),
        "auc": r["final"],
        "stream_overhead": _stream_overhead(),
    }
    assert stats["peak_staged_bytes"] <= DATA_BUDGET_MB * 1e6, \
        "staged high-water mark exceeded the budget"
    assert resident_mb > DATA_BUDGET_MB, \
        "budget must be one the resident path exceeds"
    _merge_bench_record("data_path", record)
    emit("table9/criteo/data_path", stats["epoch_s"] * 1e6,
         f"rows={DATA_PATH_ROWS};rows_per_s={rows_per_s:.0f};"
         f"window_batches={stats['window_batches']};"
         f"peak_staged_mb={stats['peak_staged_bytes'] / 1e6:.1f};"
         f"budget_mb={DATA_BUDGET_MB:.0f};"
         f"resident_mb={resident_mb:.0f};"
         f"peak_rss_mb={peak_host_mb():.0f}")


def run() -> None:
    scale = SCALE       # REPRO_BENCH_SCALE=1.0 is the full 4.5M target
    for m in METHODS:
        r = run_point(ExperimentConfig(
            method=m, dataset="criteo", scale=scale, n_epochs=EPOCHS,
            batch_size=64, w_a=8, w_p=10, seed=SEED))
        emit(f"table9/criteo/{m}", r["sim_s_per_epoch"] * 1e6,
             f"auc={r['final']:.4f};sim_s={r['sim_s']:.2f};"
             f"util={r['cpu_util']*100:.1f}%;"
             f"wait={r['waiting_per_epoch']:.3f};comm_mb={r['comm_mb']:.1f};"
             f"peak_host_mb={r['peak_host_mb']:.0f}")
    data_path()


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
