"""Paper Table 3: effect of batch size (w_a = w_p = 8)."""
from __future__ import annotations

from repro.api import ExperimentConfig

from benchmarks.common import EPOCHS, SCALE, SEED, emit, run_point

BATCHES = [16, 32, 64, 128, 256, 512, 1024]


def run() -> None:
    for B in BATCHES:
        r = run_point(ExperimentConfig(
            method="pubsub", dataset="synthetic",
            scale=max(SCALE * 0.1, 0.002), n_epochs=EPOCHS,
            batch_size=B, w_a=8, w_p=8, seed=SEED))
        emit(f"table3/B={B}", r["sim_s_per_epoch"] * 1e6,
             f"auc={r['final']:.4f};sim_s={r['sim_s']:.2f};"
             f"util={r['cpu_util']*100:.2f}%;"
             f"wait={r['waiting_per_epoch']:.4f};comm_mb={r['comm_mb']:.1f}")


if __name__ == "__main__":
    from benchmarks.common import emit_header
    emit_header()
    run()
