"""Split-inference serving example: batched autoregressive decode through
the two-party split with per-layer KV/recurrent caches.

    PYTHONPATH=src python examples/serve_split.py --arch recurrentgemma-9b
"""
import argparse
import subprocess
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    # delegate to the launch driver (the public serving entry point)
    sys.argv = ["serve", "--arch", args.arch, "--batch", str(args.batch),
                "--gen", str(args.gen)]
    from repro.launch.serve import main as serve_main
    serve_main()


if __name__ == "__main__":
    main()
