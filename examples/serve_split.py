"""Split-inference serving example: continuous-batching autoregressive
decode through the two-party split with per-slot KV/recurrent caches.

    PYTHONPATH=src python examples/serve_split.py --arch recurrentgemma-9b
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--load", type=float, default=None,
                    help="offered QPS for open-loop mode")
    args = ap.parse_args()
    # delegate to the launch driver (the public serving entry point)
    from repro.launch.serve import main as serve_main
    argv = ["--arch", args.arch, "--batch", str(args.batch),
            "--gen", str(args.gen)]
    if args.load:
        argv += ["--load", str(args.load)]
    serve_main(argv)


if __name__ == "__main__":
    main()
