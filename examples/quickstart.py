"""Quickstart: PubSub-VFL vs the four baselines on the Bank dataset.

Runs the full pipeline — synthetic data, PSI alignment, DES runtime, real
JAX training — and prints the paper's headline comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.runtime import ExperimentConfig, run_experiment  # noqa: E402

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")


def main():
    print(f"{'method':10s} {'AUC':>7s} {'sim_s':>8s} {'speedup':>8s} "
          f"{'cpu%':>6s} {'wait/ep':>8s} {'comm MB':>8s}")
    base = None
    for m in METHODS:
        r = run_experiment(ExperimentConfig(
            method=m, dataset="bank", scale=0.1, n_epochs=5,
            batch_size=64, w_a=8, w_p=10))
        if base is None:
            base = r["sim_s"]
        print(f"{m:10s} {r['final']:7.4f} {r['sim_s']:8.3f} "
              f"{base / r['sim_s']:7.2f}x {r['cpu_util'] * 100:6.2f} "
              f"{r['waiting_per_epoch']:8.4f} {r['comm_mb']:8.1f}")
    print("\n(sim_s = simulated wall-clock from the calibrated cost model;"
          "\n accuracy/convergence are real JAX training — DESIGN.md §3)")


if __name__ == "__main__":
    main()
