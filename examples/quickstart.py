"""Quickstart: PubSub-VFL vs the four baselines on the Bank dataset,
through the staged Session API.

Runs the full pipeline — synthetic data, PSI alignment, DES runtime, real
JAX training — and prints the paper's headline comparison.  Each method
is one `Session`: `prepare -> plan -> simulate -> compile -> run`, with
every stage inspectable (the DES artifact is used below to report
simulated time before training even starts).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.api import ExperimentConfig, Session  # noqa: E402

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")


def main():
    print(f"{'method':10s} {'AUC':>7s} {'sim_s':>8s} {'speedup':>8s} "
          f"{'cpu%':>6s} {'wait/ep':>8s} {'comm MB':>8s}")
    base = None
    for m in METHODS:
        sess = Session(ExperimentConfig(
            method=m, dataset="bank", scale=0.1, n_epochs=5,
            batch_size=64, w_a=8, w_p=10))
        sim = sess.simulate()         # DES system metrics, pre-training
        r = sess.run()                # real JAX training
        if base is None:
            base = sim.total_time
        print(f"{m:10s} {r['final']:7.4f} {r['sim_s']:8.3f} "
              f"{base / r['sim_s']:7.2f}x {r['cpu_util'] * 100:6.2f} "
              f"{r['waiting_per_epoch']:8.4f} {r['comm_mb']:8.1f}")
    print("\n(sim_s = simulated wall-clock from the calibrated cost model;"
          "\n accuracy/convergence are real JAX training — DESIGN.md §3)")


if __name__ == "__main__":
    main()
