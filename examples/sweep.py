"""Sweep reuse demo: N same-shape points, ONE compiled program.

Runs a seed sweep through `run_sweep` and prints the per-point wall
clock and compile-cache stats.  The second and later points skip the
DES, schedule lowering and XLA tracing entirely (and data prep too for
points sharing the data seed) — the compile-once/run-many path the
Session API exists for.

    PYTHONPATH=src python examples/sweep.py [n_points] [--stacked]

With ``--stacked`` the same points are then re-run point-stacked
(`run_sweep(..., stacked=True)`): the whole structural group executes
as ONE vmapped device program against the already-cached compile, and
per-point finals are asserted equal to the sequential path.

Exits non-zero if the warm points did not hit the compile cache, or (in
stacked mode) if the group did not stack / the per-point results
diverge (used as the CI smoke assertion).
"""
import sys

sys.path.insert(0, "src")

from repro.api import ExperimentConfig, run_sweep  # noqa: E402


def _cfgs(n_points: int):
    return [ExperimentConfig(method="pubsub", dataset="bank", scale=0.05,
                             n_epochs=3, batch_size=64, w_a=4, w_p=4,
                             seed=s) for s in range(n_points)]


def main(n_points: int = 2, stacked: bool = False) -> int:
    sw = run_sweep(_cfgs(n_points))
    for i, r in enumerate(sw.results):
        kind = "warm (cache hit)" if r.compile_cache_hit else "cold"
        print(f"point {i}: seed={r.seed} final={r['final']:.4f} "
              f"wall={r.wall_s:6.2f}s  {kind}")
    s = sw.stats
    print(f"\ncompiles={s['compiles']} cache_hits={s['cache_hits']} "
          f"cold_mean={s['cold_wall_s_mean']:.2f}s "
          f"warm_mean={s['warm_wall_s_mean']:.2f}s")
    if s["compiles"] != 1 or s["cache_hits"] != n_points - 1:
        print("ERROR: expected exactly one compile and "
              f"{n_points - 1} cache hits", file=sys.stderr)
        return 1
    print(f"amortization: warm points ran "
          f"{s['cold_wall_s_mean'] / max(s['warm_wall_s_mean'], 1e-9):.1f}x "
          f"faster than the cold point")
    if not stacked:
        return 0

    # stack_chunk pins the whole group into ONE vmapped device program
    # (the CPU default would tile into per-point chunks), so this smoke
    # genuinely exercises the vmapped stacked path
    st = run_sweep(_cfgs(n_points), stacked=True, stack_chunk=n_points)
    ss = st.stats
    print(f"\nstacked: groups={ss['points_per_group']} "
          f"stacked_groups={ss['stacked_groups']} "
          f"compiles={ss['compiles']} wall={ss['wall_s']:.2f}s "
          f"(sequential {s['wall_s']:.2f}s)")
    if ss["compiles"] != 0 or ss["stacked_groups"] != 1 or \
            ss["points_per_group"] != [n_points]:
        print("ERROR: stacked sweep should reuse the one compiled "
              "program and stack all points into one group",
              file=sys.stderr)
        return 1
    for i, (a, b) in enumerate(zip(sw.results, st.results)):
        if a["final"] != b["final"] or \
                a.train.history != b.train.history:
            print(f"ERROR: stacked point {i} diverged from sequential "
                  f"({a['final']} vs {b['final']})", file=sys.stderr)
            return 1
    print("stacked finals match the sequential path bit-for-bit")
    return 0


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]]
    stacked = "--stacked" in args
    args = [a for a in args if a != "--stacked"]
    n = int(args[0]) if args else 2
    raise SystemExit(main(n, stacked=stacked))
