"""Sweep reuse demo: N same-shape points, ONE compiled program.

Runs a seed sweep through `run_sweep` and prints the per-point wall
clock and compile-cache stats.  The second and later points skip the
DES, schedule lowering and XLA tracing entirely (and data prep too for
points sharing the data seed) — the compile-once/run-many path the
Session API exists for.

    PYTHONPATH=src python examples/sweep.py [n_points]

Exits non-zero if the warm points did not hit the compile cache (used
as the CI smoke assertion).
"""
import sys

sys.path.insert(0, "src")

from repro.api import ExperimentConfig, run_sweep  # noqa: E402


def main(n_points: int = 2) -> int:
    cfgs = [ExperimentConfig(method="pubsub", dataset="bank", scale=0.05,
                             n_epochs=3, batch_size=64, w_a=4, w_p=4,
                             seed=s) for s in range(n_points)]
    sw = run_sweep(cfgs)
    for i, r in enumerate(sw.results):
        kind = "warm (cache hit)" if r.compile_cache_hit else "cold"
        print(f"point {i}: seed={r.seed} final={r['final']:.4f} "
              f"wall={r.wall_s:6.2f}s  {kind}")
    s = sw.stats
    print(f"\ncompiles={s['compiles']} cache_hits={s['cache_hits']} "
          f"cold_mean={s['cold_wall_s_mean']:.2f}s "
          f"warm_mean={s['warm_wall_s_mean']:.2f}s")
    if s["compiles"] != 1 or s["cache_hits"] != n_points - 1:
        print("ERROR: expected exactly one compile and "
              f"{n_points - 1} cache hits", file=sys.stderr)
        return 1
    print(f"amortization: warm points ran "
          f"{s['cold_wall_s_mean'] / max(s['warm_wall_s_mean'], 1e-9):.1f}x "
          f"faster than the cold point")
    return 0


if __name__ == "__main__":
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 2
    raise SystemExit(main(n))
