"""CI streaming smoke: a tiny config forced through the windowed data
path, asserted bit-for-bit against the resident run and against the
staging budget.

    PYTHONPATH=src python examples/streaming_smoke.py

Exits non-zero if:
  * the epoch is not actually windowed (< 2 windows),
  * any loss / metric differs from the resident run in any bit,
  * the staged-bytes high-water mark (the double buffer) exceeds the
    configured `data_budget_mb`,
  * the resident fallthrough engages streaming when everything fits.
"""
from __future__ import annotations

import sys

from repro.api import ExperimentConfig, Session

BUDGET_MB = 0.2

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=2,
            batch_size=64, w_a=4, w_p=4, dp_mu=0.5, seed=0)


def main() -> int:
    resident = Session(ExperimentConfig(**BASE)).run()
    if resident.data_path is not None:
        print("FAIL: resident run reported streaming stats")
        return 1

    # small budget + forced streaming: a multi-window epoch
    streamed = Session(ExperimentConfig(
        **BASE, stream=True, stream_backing="wrap",
        data_budget_mb=BUDGET_MB)).run()
    stats = streamed.data_path
    if stats is None:
        print("FAIL: streaming run reported no data-path stats")
        return 1
    windows = stats["windows_per_epoch"]
    print(f"windows/epoch={windows} window_batches={stats['window_batches']}"
          f" peak_staged={stats['peak_staged_bytes']} B"
          f" budget={BUDGET_MB} MB")
    if any(w < 2 for w in windows):
        print("FAIL: expected every epoch to run >= 2 windows")
        return 1
    if stats["peak_staged_bytes"] > BUDGET_MB * 1e6:
        print("FAIL: staged high-water mark exceeded the budget")
        return 1
    for field in ("losses", "history", "final_metric"):
        a, b = getattr(resident.train, field), getattr(streamed.train, field)
        if a != b:
            print(f"FAIL: streamed {field} diverged from resident\n"
                  f"  resident : {a}\n  streamed : {b}")
            return 1
    print(f"parity OK: losses/history/final bit-identical; "
          f"final={streamed.train.final_metric:.4f}")

    # a budget everything fits under: prepare() stays resident
    roomy = Session(ExperimentConfig(**BASE, data_budget_mb=1024.0))
    if roomy._streaming() or roomy.prepare().streaming:
        print("FAIL: resident fallthrough engaged streaming")
        return 1
    print("resident fallthrough OK (1 GB budget on a ~0.1 MB dataset)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
