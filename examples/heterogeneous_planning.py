"""System profiling + planning walkthrough (paper §4.2-4.3).

1. Profile this host: time the real jitted VFL ops over a batch grid and
   fit the per-sample power law (Table 8 procedure).
2. Plan: run the DP search (Algorithm 2) for several core splits.
3. Show the planned config beating a naive fixed config in the DES.

    PYTHONPATH=src python examples/heterogeneous_planning.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.cost_model import PartyProfile, SystemProfile  # noqa: E402
from repro.core.des import RunConfig, simulate                 # noqa: E402
from repro.core.planner import plan                            # noqa: E402
from repro.core.profiler import profile_host                   # noqa: E402


def main():
    print("== profiling this host (real jitted ops) ==")
    consts, rows = profile_host(batch_sizes=(16, 32, 64, 128))
    print(f"fitted: lambda_p={consts.lambda_p:.2e} "
          f"gamma_p={consts.gamma_p:+.3f}  "
          f"varphi_p={consts.varphi_p:.2e} beta_p={consts.beta_p:+.3f}")

    print("\n== planning (Algorithm 2) across core splits ==")
    for ca, cp in [(32, 32), (50, 14), (40, 24)]:
        prof = SystemProfile(active=PartyProfile(cores=ca),
                             passive=PartyProfile(cores=cp))
        p_paper = plan(prof, w_a_range=(2, 16), w_p_range=(2, 16),
                       objective="paper")
        p = plan(prof, w_a_range=(2, 16), w_p_range=(2, 16),
                 objective="throughput")
        print(f"cores {ca}:{cp} -> Eq.14-literal: {p_paper.summary()}")
        print(f"            -> throughput (ours): {p.summary()}")

        naive = RunConfig(method="pubsub", n_samples=30000, batch_size=256,
                          n_epochs=3, w_a=8, w_p=8, profile=prof)
        planned = RunConfig(method="pubsub", n_samples=30000,
                            batch_size=p.batch_size, n_epochs=3,
                            w_a=p.w_a, w_p=p.w_p, profile=prof)
        rn, rp = simulate(naive), simulate(planned)
        print(f"  naive (8,8,256): {rn.total_time:7.2f}s "
              f"util={rn.cpu_util * 100:5.1f}%   planned: "
              f"{rp.total_time:7.2f}s util={rp.cpu_util * 100:5.1f}%")


if __name__ == "__main__":
    main()
