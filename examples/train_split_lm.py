"""End-to-end driver: train a split LLM backbone (the paper's technique
applied to an assigned architecture) for a few hundred steps.

The passive party holds the token stream and the bottom stack; the cut
layer applies the L2-clip + Gaussian-DP mechanism; the active party holds
f_a + the top stack + head.  Default is a CPU-sized config; --full trains
the ~0.5B qwen2-0.5b (hours on CPU; the dry-run covers the full mesh).

    PYTHONPATH=src python examples/train_split_lm.py --arch rwkv6-1.6b \
        --steps 100
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                              # noqa: E402
import jax.numpy as jnp                                  # noqa: E402
import numpy as np                                       # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.launch.steps import make_model, make_train_step  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = make_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"layers={cfg.n_layers} cut@{cfg.resolved_cut}")

    opt, train_step = make_train_step(model, lr=3e-4,
                                      dp_sigma=args.dp_sigma,
                                      dp_clip=1.0 if args.dp_sigma else 1e9)
    opt_state = opt.init(params)
    step_fn = jax.jit(train_step)

    # synthetic structured stream: next token = (3*tok + 7) % V with noise,
    # so the loss has a learnable signal and should clearly decrease
    rng = np.random.default_rng(0)
    V = cfg.vocab_size
    B, S = args.batch, args.seq
    t0 = time.time()
    first = None
    for step in range(args.steps):
        key, sub = jax.random.split(key)
        start = rng.integers(0, V, size=(B, 1))
        seq = [start]
        for _ in range(S):
            nxt = (3 * seq[-1] + 7) % V
            flip = rng.random((B, 1)) < 0.05
            nxt = np.where(flip, rng.integers(0, V, size=(B, 1)), nxt)
            seq.append(nxt)
        toks = np.concatenate(seq, axis=1)
        batch = {"tokens_p": jnp.asarray(toks[:, :S], jnp.int32),
                 "labels": jnp.asarray(toks[:, :S], jnp.int32),
                 "x_a": jnp.zeros((B, S, cfg.d_active), jnp.float32)}
        params, opt_state, loss = step_fn(params, opt_state, batch, sub)
        if first is None:
            first = float(loss)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    print(f"loss: {first:.3f} -> {float(loss):.3f} "
          f"({'improved' if float(loss) < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
