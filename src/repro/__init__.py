"""PubSub-VFL (NeurIPS 2025) reproduction + multi-pod JAX framework."""
__version__ = "1.0.0"
