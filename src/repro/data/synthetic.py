"""Synthetic doppelgängers of the paper's five benchmark datasets.

The container has no network access (DESIGN.md §5), so each dataset is
regenerated with matched cardinality/feature count and task type:

  Energy    19,735 x  27  regression   (appliances energy)
  Blog      60,021 x 280  regression   (zero-inflated comment counts)
  Bank      40,787 x  48  classification
  Credit    30,000 x  23  classification
  Synthetic n x 500       classification (paper: 1M; default reduced)
  Criteo    n x  39       classification (paper: 4.5B; heavily reduced)

Classification generators follow sklearn.make_classification: informative
features on gaussian class centroids + redundant linear mixtures + noise.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass
class Dataset:
    name: str
    X: np.ndarray          # (n, d) float32
    y: np.ndarray          # (n,) float32 (regression) or int64 {0,1}
    task: str              # "regression" | "classification"

    @property
    def n(self):
        return self.X.shape[0]

    @property
    def d(self):
        return self.X.shape[1]

    def split(self, frac: float = 0.7, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n)
        k = int(self.n * frac)
        tr, te = idx[:k], idx[k:]
        return (Dataset(self.name, self.X[tr], self.y[tr], self.task),
                Dataset(self.name, self.X[te], self.y[te], self.task))


def _make_classification(n, d, n_informative, seed, class_sep=1.0,
                         flip_y=0.01):
    rng = np.random.default_rng(seed)
    n_redundant = max(0, min(d - n_informative, n_informative))
    n_noise = d - n_informative - n_redundant
    y = rng.integers(0, 2, size=n)
    centroids = rng.normal(size=(2, n_informative)) * class_sep
    Xi = centroids[y] + rng.normal(size=(n, n_informative))
    A = rng.normal(size=(n_informative, n_redundant))
    Xr = Xi @ A / np.sqrt(n_informative)
    Xn = rng.normal(size=(n, n_noise))
    X = np.concatenate([Xi, Xr, Xn], axis=1)
    X = X[:, rng.permutation(d)]
    flip = rng.random(n) < flip_y
    y = np.where(flip, 1 - y, y)
    return X.astype(np.float32), y.astype(np.int64)


def _make_regression(n, d, n_informative, seed, noise=0.1,
                     zero_inflate=0.0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    w = np.zeros(d)
    idx = rng.choice(d, n_informative, replace=False)
    w[idx] = rng.normal(size=n_informative)
    y = X @ w + np.sin(X[:, idx[0]] * 2.0) + noise * rng.normal(size=n)
    if zero_inflate > 0:
        y = np.where(rng.random(n) < zero_inflate, 0.0, np.abs(y))
    # standardize target to keep RMSEs comparable across methods
    y = (y - y.mean()) / (y.std() + 1e-9)
    return X.astype(np.float32), y.astype(np.float32)


def load(name: str, *, seed: int = 0, scale: float = 1.0) -> Dataset:
    """scale < 1 shrinks sample counts (CI-friendly)."""
    name = name.lower()
    def sz(n):
        return max(64, int(n * scale))
    if name == "energy":
        X, y = _make_regression(sz(19_735), 27, 12, seed)
        return Dataset("energy", X, y, "regression")
    if name == "blog":
        X, y = _make_regression(sz(60_021), 280, 40, seed, zero_inflate=0.6)
        return Dataset("blog", X, y, "regression")
    if name == "bank":
        X, y = _make_classification(sz(40_787), 48, 16, seed, class_sep=1.4)
        return Dataset("bank", X, y, "classification")
    if name == "credit":
        X, y = _make_classification(sz(30_000), 23, 10, seed, class_sep=1.0)
        return Dataset("credit", X, y, "classification")
    if name == "synthetic":
        X, y = _make_classification(sz(1_000_000), 500, 40, seed,
                                    class_sep=1.2)
        return Dataset("synthetic", X, y, "classification")
    if name == "criteo":
        X, y = _make_classification(sz(4_500_000), 39, 20, seed,
                                    class_sep=0.8, flip_y=0.1)
        return Dataset("criteo", X, y, "classification")
    raise KeyError(name)


DATASETS = ["energy", "blog", "bank", "credit", "synthetic"]

# base (n, d, task) of each generator before `scale` — lets the streaming
# path size budgets and shard layouts without materializing anything
_SHAPES = {
    "energy":    (19_735, 27, "regression"),
    "blog":      (60_021, 280, "regression"),
    "bank":      (40_787, 48, "classification"),
    "credit":    (30_000, 23, "classification"),
    "synthetic": (1_000_000, 500, "classification"),
    "criteo":    (4_500_000, 39, "classification"),
}

# classification generator params (n_informative, class_sep, flip_y),
# shared by `load` above and the chunked generator below
_CLS_PARAMS = {
    "bank": (16, 1.4, 0.01),
    "credit": (10, 1.0, 0.01),
    "synthetic": (40, 1.2, 0.01),
    "criteo": (20, 0.8, 0.1),
}


def shape_of(name: str, scale: float = 1.0) -> Tuple[int, int, str]:
    """(n_samples, n_features, task) of `load(name, scale=scale)` without
    generating any data."""
    n, d, task = _SHAPES[name.lower()]
    return max(64, int(n * scale)), d, task


def iter_classification_chunks(name: str, n: int, *, seed: int,
                               chunk_rows: int = 131_072
                               ) -> Iterator[Tuple[int, np.ndarray,
                                                   np.ndarray]]:
    """Yield (row_offset, X_chunk float32, y_chunk int64) blocks of a
    classification dataset, never holding more than one chunk.

    The class centroids, redundant mixture and column permutation are
    drawn once from the base seed; per-chunk sample draws come from a
    SeedSequence spawned on (seed, chunk_index), so the stream is
    deterministic for a given (name, n, seed, chunk_rows) and any chunk
    can in principle be regenerated independently.  Note this is a
    *different* (chunk-invariant, memory-bounded) draw order than the
    resident `load()` — the streaming shards back a distinct dataset
    instance, not a re-encoding of the resident one."""
    name = name.lower()
    if name not in _CLS_PARAMS:
        raise ValueError(f"chunked generation supports classification "
                         f"datasets {sorted(_CLS_PARAMS)}, not {name!r}")
    n_informative, class_sep, flip_y = _CLS_PARAMS[name]
    d = _SHAPES[name][1]
    rng0 = np.random.default_rng(seed)
    n_redundant = max(0, min(d - n_informative, n_informative))
    n_noise = d - n_informative - n_redundant
    centroids = rng0.normal(size=(2, n_informative)) * class_sep
    A = rng0.normal(size=(n_informative, n_redundant))
    col_perm = rng0.permutation(d)
    for ci, lo in enumerate(range(0, n, chunk_rows)):
        k = min(chunk_rows, n - lo)
        rng = np.random.default_rng(np.random.SeedSequence((seed, ci)))
        y = rng.integers(0, 2, size=k)
        Xi = centroids[y] + rng.normal(size=(k, n_informative))
        Xr = Xi @ A / np.sqrt(n_informative)
        Xn = rng.normal(size=(k, n_noise))
        X = np.concatenate([Xi, Xr, Xn], axis=1)[:, col_perm]
        flip = rng.random(k) < flip_y
        y = np.where(flip, 1 - y, y)
        yield lo, X.astype(np.float32), y.astype(np.int64)


def write_sharded(name: str, root: str, *, seed: int = 0,
                  scale: float = 1.0, chunk_rows: int = 131_072,
                  passive_frac: float = 0.5,
                  n_features_active: Optional[int] = None,
                  train_frac: float = 0.7,
                  rows_per_shard: int = 262_144) -> dict:
    """Generate a dataset chunk-by-chunk straight into per-party shard
    directories (`<root>/active`, `<root>/passive`) without ever
    materializing the full (n, d) array.

    Columns are split with the same `split_columns` logic (same seed
    semantics) as the resident `vertical_split`; labels and the
    train/test ID permutation stay resident as small (n,) arrays
    (`y.npy`, `ids_train.npy`, `ids_test.npy`).  Re-invocation with
    identical parameters is a no-op (the existing `meta.json` is
    reused).  Returns the root meta dict."""
    from repro.data.vertical import split_columns  # local: avoid cycle

    n, d, task = shape_of(name, scale)
    meta_path = os.path.join(root, "meta.json")
    params = {"name": name.lower(), "n": n, "d": d, "task": task,
              "seed": seed, "scale": scale, "chunk_rows": chunk_rows,
              "passive_frac": passive_frac,
              "n_features_active": n_features_active,
              "train_frac": train_frac,
              "rows_per_shard": rows_per_shard, "version": 1}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            existing = json.load(f)
        if {k: existing.get(k) for k in params} == params:
            return existing
    cols_a, cols_p = split_columns(d, passive_frac=passive_frac,
                                   seed=seed,
                                   n_features_active=n_features_active)
    os.makedirs(root, exist_ok=True)
    from repro.data.shards import ShardWriter  # local: avoid cycle
    wa = ShardWriter(os.path.join(root, "active"), len(cols_a),
                     rows_per_shard=rows_per_shard)
    wp = ShardWriter(os.path.join(root, "passive"), len(cols_p),
                     rows_per_shard=rows_per_shard)
    y_full = np.empty(n, np.int64)
    for lo, X, y in iter_classification_chunks(name, n, seed=seed,
                                               chunk_rows=chunk_rows):
        wa.append(X[:, cols_a])
        wp.append(X[:, cols_p])
        y_full[lo:lo + len(y)] = y
    wa.close()
    wp.close()
    # same train/test convention as Dataset.split: one permutation, the
    # first `train_frac` slice trains, the remainder evaluates
    perm = np.random.default_rng(seed).permutation(n)
    k = int(n * train_frac)
    np.save(os.path.join(root, "y.npy"), y_full)
    np.save(os.path.join(root, "ids_train.npy"), perm[:k].astype(np.int64))
    np.save(os.path.join(root, "ids_test.npy"), perm[k:].astype(np.int64))
    meta = dict(params, cols_active=[int(c) for c in cols_a],
                cols_passive=[int(c) for c in cols_p],
                n_train=k, n_test=n - k)
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    return meta


def open_sharded(root: str):
    """(meta, active_store, passive_store, y, ids_train, ids_test) for a
    `write_sharded` root."""
    from repro.data.shards import ShardStore  # local: avoid cycle
    with open(os.path.join(root, "meta.json")) as f:
        meta = json.load(f)
    return (meta,
            ShardStore.open(os.path.join(root, "active")),
            ShardStore.open(os.path.join(root, "passive")),
            np.load(os.path.join(root, "y.npy")),
            np.load(os.path.join(root, "ids_train.npy")),
            np.load(os.path.join(root, "ids_test.npy")))
