"""Synthetic doppelgängers of the paper's five benchmark datasets.

The container has no network access (DESIGN.md §5), so each dataset is
regenerated with matched cardinality/feature count and task type:

  Energy    19,735 x  27  regression   (appliances energy)
  Blog      60,021 x 280  regression   (zero-inflated comment counts)
  Bank      40,787 x  48  classification
  Credit    30,000 x  23  classification
  Synthetic n x 500       classification (paper: 1M; default reduced)
  Criteo    n x  39       classification (paper: 4.5B; heavily reduced)

Classification generators follow sklearn.make_classification: informative
features on gaussian class centroids + redundant linear mixtures + noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass
class Dataset:
    name: str
    X: np.ndarray          # (n, d) float32
    y: np.ndarray          # (n,) float32 (regression) or int64 {0,1}
    task: str              # "regression" | "classification"

    @property
    def n(self):
        return self.X.shape[0]

    @property
    def d(self):
        return self.X.shape[1]

    def split(self, frac: float = 0.7, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n)
        k = int(self.n * frac)
        tr, te = idx[:k], idx[k:]
        return (Dataset(self.name, self.X[tr], self.y[tr], self.task),
                Dataset(self.name, self.X[te], self.y[te], self.task))


def _make_classification(n, d, n_informative, seed, class_sep=1.0,
                         flip_y=0.01):
    rng = np.random.default_rng(seed)
    n_redundant = max(0, min(d - n_informative, n_informative))
    n_noise = d - n_informative - n_redundant
    y = rng.integers(0, 2, size=n)
    centroids = rng.normal(size=(2, n_informative)) * class_sep
    Xi = centroids[y] + rng.normal(size=(n, n_informative))
    A = rng.normal(size=(n_informative, n_redundant))
    Xr = Xi @ A / np.sqrt(n_informative)
    Xn = rng.normal(size=(n, n_noise))
    X = np.concatenate([Xi, Xr, Xn], axis=1)
    X = X[:, rng.permutation(d)]
    flip = rng.random(n) < flip_y
    y = np.where(flip, 1 - y, y)
    return X.astype(np.float32), y.astype(np.int64)


def _make_regression(n, d, n_informative, seed, noise=0.1,
                     zero_inflate=0.0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    w = np.zeros(d)
    idx = rng.choice(d, n_informative, replace=False)
    w[idx] = rng.normal(size=n_informative)
    y = X @ w + np.sin(X[:, idx[0]] * 2.0) + noise * rng.normal(size=n)
    if zero_inflate > 0:
        y = np.where(rng.random(n) < zero_inflate, 0.0, np.abs(y))
    # standardize target to keep RMSEs comparable across methods
    y = (y - y.mean()) / (y.std() + 1e-9)
    return X.astype(np.float32), y.astype(np.float32)


def load(name: str, *, seed: int = 0, scale: float = 1.0) -> Dataset:
    """scale < 1 shrinks sample counts (CI-friendly)."""
    name = name.lower()
    def sz(n):
        return max(64, int(n * scale))
    if name == "energy":
        X, y = _make_regression(sz(19_735), 27, 12, seed)
        return Dataset("energy", X, y, "regression")
    if name == "blog":
        X, y = _make_regression(sz(60_021), 280, 40, seed, zero_inflate=0.6)
        return Dataset("blog", X, y, "regression")
    if name == "bank":
        X, y = _make_classification(sz(40_787), 48, 16, seed, class_sep=1.4)
        return Dataset("bank", X, y, "classification")
    if name == "credit":
        X, y = _make_classification(sz(30_000), 23, 10, seed, class_sep=1.0)
        return Dataset("credit", X, y, "classification")
    if name == "synthetic":
        X, y = _make_classification(sz(1_000_000), 500, 40, seed,
                                    class_sep=1.2)
        return Dataset("synthetic", X, y, "classification")
    if name == "criteo":
        X, y = _make_classification(sz(4_500_000), 39, 20, seed,
                                    class_sep=0.8, flip_y=0.1)
        return Dataset("criteo", X, y, "classification")
    raise KeyError(name)


DATASETS = ["energy", "blog", "bank", "credit", "synthetic"]
