"""Vertical (feature-wise) partitioning + PSI alignment + batch iterator.

In VFL the two parties hold different feature columns of the same samples.
`psi_align` performs the paper's pre-training Private Set Intersection step
(hash-based; both parties learn only the intersection of sample IDs).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic import Dataset


@dataclass
class VerticalView:
    """One party's view: features only; labels only at the active party."""
    ids: np.ndarray
    X: np.ndarray
    y: Optional[np.ndarray]      # None at the passive party


def vertical_split(ds: Dataset, passive_frac: float = 0.5, *, seed: int = 0,
                   n_features_active: Optional[int] = None
                   ) -> Tuple[VerticalView, VerticalView]:
    """Returns (active_view, passive_view) with disjoint feature columns.

    `n_features_active` overrides the fraction (paper's data-heterogeneity
    sweeps use explicit 50:450 style splits)."""
    rng = np.random.default_rng(seed)
    d = ds.d
    perm = rng.permutation(d)
    if n_features_active is None:
        n_a = d - int(d * passive_frac)
    else:
        n_a = n_features_active
    n_a = int(np.clip(n_a, 1, d - 1))
    cols_a, cols_p = perm[:n_a], perm[n_a:]
    ids = np.arange(ds.n, dtype=np.int64)
    active = VerticalView(ids, ds.X[:, cols_a], ds.y)
    passive = VerticalView(ids, ds.X[:, cols_p], None)
    return active, passive


def _hash_ids(ids: np.ndarray, salt: bytes) -> np.ndarray:
    out = np.empty(len(ids), dtype="U32")
    for i, v in enumerate(ids):
        out[i] = hashlib.sha256(salt + int(v).to_bytes(8, "little")
                                ).hexdigest()[:32]
    return out


def psi_align(active: VerticalView, passive: VerticalView, *,
              salt: bytes = b"psi-session") -> Tuple[VerticalView,
                                                     VerticalView]:
    """Hash-based PSI (stand-in for [38]): both sides hash their IDs with a
    shared session salt; only hashes are exchanged; rows are reordered to
    the sorted intersection so batch i refers to the same samples."""
    ha = _hash_ids(active.ids, salt)
    hp = _hash_ids(passive.ids, salt)
    common, ia, ip = np.intersect1d(ha, hp, return_indices=True)
    return (VerticalView(active.ids[ia], active.X[ia],
                         None if active.y is None else active.y[ia]),
            VerticalView(passive.ids[ip], passive.X[ip], None))


def batch_ids(n: int, batch_size: int, *, seed: int, epoch: int
              ) -> np.ndarray:
    """Deterministic epoch shuffling shared by both parties (they hold the
    same aligned index space after PSI); returns (n_batches, B) indices."""
    rng = np.random.default_rng(seed + epoch * 9973)
    idx = rng.permutation(n)
    if batch_size >= n:
        return idx[None, :]                     # single full batch
    n_batches = n // batch_size
    return idx[:n_batches * batch_size].reshape(n_batches, batch_size)
