"""Vertical (feature-wise) partitioning + PSI alignment + batch iterator.

In VFL the two parties hold different feature columns of the same samples.
`psi_align` performs the paper's pre-training Private Set Intersection step
(hash-based; both parties learn only the intersection of sample IDs).

The PSI is chunked and vectorized for paper-scale ID sets: IDs are
serialized per chunk through one contiguous byte buffer (no per-row
Python int conversion), every digest reuses a pre-hashed salt prefix,
and the intersection runs on the 128-bit truncated digests as uint64
word pairs (one lexsort-merge instead of `np.intersect1d` over U32
strings).  The digests — and therefore the aligned row order, which is
sorted by digest — are bit-identical to the original per-row
`hashlib.sha256(salt + id.to_bytes(8, "little"))` loop (pinned by
tests/test_streaming_data.py).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic import Dataset

PSI_CHUNK = 1 << 16          # IDs hashed per byte-buffer chunk


@dataclass
class VerticalView:
    """One party's view: features only; labels only at the active party.

    `X` is normally an in-RAM ``(n, d)`` ndarray; the streaming data path
    substitutes a row-gatherable feature source (`repro.data.shards`)
    with the same ``shape``/``__getitem__`` surface."""
    ids: np.ndarray
    X: np.ndarray
    y: Optional[np.ndarray]      # None at the passive party


def split_columns(d: int, *, passive_frac: float = 0.5, seed: int = 0,
                  n_features_active: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """The (cols_active, cols_passive) column partition used by
    `vertical_split` — factored out so the shard-writing generator
    (`data.synthetic.write_sharded`) splits columns identically."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(d)
    if n_features_active is None:
        n_a = d - int(d * passive_frac)
    else:
        n_a = n_features_active
    n_a = int(np.clip(n_a, 1, d - 1))
    return perm[:n_a], perm[n_a:]


def vertical_split(ds: Dataset, passive_frac: float = 0.5, *, seed: int = 0,
                   n_features_active: Optional[int] = None
                   ) -> Tuple[VerticalView, VerticalView]:
    """Returns (active_view, passive_view) with disjoint feature columns.

    `n_features_active` overrides the fraction (paper's data-heterogeneity
    sweeps use explicit 50:450 style splits)."""
    cols_a, cols_p = split_columns(ds.d, passive_frac=passive_frac,
                                   seed=seed,
                                   n_features_active=n_features_active)
    ids = np.arange(ds.n, dtype=np.int64)
    active = VerticalView(ids, ds.X[:, cols_a], ds.y)
    passive = VerticalView(ids, ds.X[:, cols_p], None)
    return active, passive


def _id_buffer(ids: np.ndarray) -> memoryview:
    """One contiguous little-endian byte buffer for a chunk of int64 IDs
    (the vectorized replacement for per-row `int(v).to_bytes`)."""
    return memoryview(np.ascontiguousarray(ids, dtype="<i8").tobytes())


def _hash_ids(ids: np.ndarray, salt: bytes, *,
              chunk: int = PSI_CHUNK) -> np.ndarray:
    """Hex digests (first 32 chars of sha256) of `salt || id_le64`.

    Chunked: each chunk of IDs is serialized through a single bytes
    buffer and every row's digest starts from one pre-hashed salt state
    — no per-row int conversion or salt re-hash — producing digests
    byte-identical to the original per-row loop."""
    ids = np.asarray(ids, np.int64)
    out = np.empty(len(ids), dtype="U32")
    h0 = hashlib.sha256(salt)
    pos = 0
    for lo in range(0, len(ids), chunk):
        buf = _id_buffer(ids[lo:lo + chunk])
        for j in range(len(buf) // 8):
            h = h0.copy()
            h.update(buf[8 * j:8 * j + 8])
            out[pos] = h.hexdigest()[:32]
            pos += 1
    return out


def _digest_words(ids: np.ndarray, salt: bytes, *,
                  chunk: int = PSI_CHUNK) -> np.ndarray:
    """(n, 2) big-endian uint64 words of the 128-bit truncated digests.

    Lexicographic order on the word pairs equals lexicographic order on
    the hex digests `_hash_ids` returns (hex is order-preserving), so the
    intersection/sort below reproduces the legacy U32-string behavior at
    1/8th the memory and without string comparisons."""
    ids = np.asarray(ids, np.int64)
    raw = np.empty((len(ids), 16), np.uint8)
    h0 = hashlib.sha256(salt)
    pos = 0
    for lo in range(0, len(ids), chunk):
        buf = _id_buffer(ids[lo:lo + chunk])
        for j in range(len(buf) // 8):
            h = h0.copy()
            h.update(buf[8 * j:8 * j + 8])
            raw[pos] = np.frombuffer(h.digest(), np.uint8, count=16)
            pos += 1
    return raw.view(">u8").reshape(len(ids), 2)


def psi_intersect(ids_a: np.ndarray, ids_p: np.ndarray, *,
                  salt: bytes = b"psi-session",
                  chunk: int = PSI_CHUNK
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Chunked hash-based PSI on raw ID arrays: returns (ia, ip) index
    arrays such that ``ids_a[ia] == ids_p[ip]`` row-for-row, ordered by
    ascending digest — exactly the row order the legacy
    `np.intersect1d(hash_a, hash_p)` produced.  IDs must be unique
    within each party (standard PSI precondition).  Used directly by the
    streaming data path, which aligns shard-store row permutations
    without materializing feature arrays."""
    da = _digest_words(ids_a, salt, chunk=chunk)
    dp_ = _digest_words(ids_p, salt, chunk=chunk)
    na, np_ = len(da), len(dp_)
    hi = np.concatenate([da[:, 0], dp_[:, 0]])
    lo = np.concatenate([da[:, 1], dp_[:, 1]])
    src = np.concatenate([np.zeros(na, bool), np.ones(np_, bool)])
    idx = np.concatenate([np.arange(na, dtype=np.int64),
                          np.arange(np_, dtype=np.int64)])
    # sort by digest; within a shared digest the active row comes first,
    # so every common digest is an adjacent (active, passive) pair
    order = np.lexsort((src, lo, hi))
    hi, lo, src, idx = hi[order], lo[order], src[order], idx[order]
    m = (hi[1:] == hi[:-1]) & (lo[1:] == lo[:-1]) & \
        (~src[:-1]) & src[1:]
    return idx[:-1][m], idx[1:][m]


def psi_align(active: VerticalView, passive: VerticalView, *,
              salt: bytes = b"psi-session") -> Tuple[VerticalView,
                                                     VerticalView]:
    """Hash-based PSI (stand-in for [38]): both sides hash their IDs with a
    shared session salt; only hashes are exchanged; rows are reordered to
    the sorted intersection so batch i refers to the same samples."""
    ia, ip = psi_intersect(active.ids, passive.ids, salt=salt)
    return (VerticalView(active.ids[ia], active.X[ia],
                         None if active.y is None else active.y[ia]),
            VerticalView(passive.ids[ip], passive.X[ip], None))


def batch_ids(n: int, batch_size: int, *, seed: int, epoch: int
              ) -> np.ndarray:
    """Deterministic epoch shuffling shared by both parties (they hold the
    same aligned index space after PSI); returns (n_batches, B) indices."""
    rng = np.random.default_rng(seed + epoch * 9973)
    idx = rng.permutation(n)
    if batch_size >= n:
        return idx[None, :]                     # single full batch
    n_batches = n // batch_size
    return idx[:n_batches * batch_size].reshape(n_batches, batch_size)
