"""Per-party on-disk feature shard store for the streaming data path.

Each party owns its own shard directory — feature rows never cross the
party/trust boundary on disk, mirroring the paper's deployment where the
publisher and subscribers hold disjoint feature columns:

    <party_dir>/meta.json        {"n", "d", "dtype", "rows_per_shard", ...}
    <party_dir>/shard_00000.npy  rows [0, rows_per_shard)
    <party_dir>/shard_00001.npy  rows [rows_per_shard, 2*rows_per_shard)
    ...

`ShardWriter` appends feature chunks (bounded memory, any chunk size) and
`ShardStore` reads them back through lazily-opened ``np.load(mmap_mode="r")``
handles, so a gather touches only the pages holding the requested rows.

Everything downstream of `Session.prepare()` consumes features through the
minimal *feature source* surface:

    src.shape  -> (n, d)
    src.dtype
    src[rows]  -> np.ndarray (len(rows), d)   # arbitrary int row gather

`ShardStore`, `Permuted` (a PSI row-permutation view) and `ArrayFeatures`
(an in-RAM array opted into windowed staging) all implement it, which is
what lets the compiled replay engine's windowed `stage_data` and the event
engine's per-event gathers stream from RAM or disk interchangeably.
"""
from __future__ import annotations

import json
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Tuple

import numpy as np

META_NAME = "meta.json"
DEFAULT_ROWS_PER_SHARD = 262_144

# below this many requested rows a gather runs sequentially even when a
# pool is available — thread dispatch costs more than the reads
_PARALLEL_MIN_ROWS = 4096


def is_feature_source(x) -> bool:
    """True for streaming feature sources (anything gatherable by row that
    is not a plain ndarray)."""
    return hasattr(x, "gather") and not isinstance(x, np.ndarray)


class ShardWriter:
    """Append-only writer producing the shard layout above.

    Peak memory is one shard (`rows_per_shard * d * itemsize`), regardless
    of total rows or of the chunk sizes appended."""

    def __init__(self, party_dir: str, d: int, *,
                 rows_per_shard: int = DEFAULT_ROWS_PER_SHARD,
                 dtype=np.float32):
        os.makedirs(party_dir, exist_ok=True)
        self.dir = party_dir
        self.d = int(d)
        self.rows_per_shard = int(rows_per_shard)
        self.dtype = np.dtype(dtype)
        self._buf = np.empty((self.rows_per_shard, self.d), self.dtype)
        self._fill = 0                     # rows currently buffered
        self._n = 0                        # total rows written + buffered
        self._n_shards = 0

    def append(self, block: np.ndarray) -> None:
        block = np.asarray(block, self.dtype)
        if block.ndim != 2 or block.shape[1] != self.d:
            raise ValueError(f"expected (k, {self.d}) block, "
                             f"got {block.shape}")
        pos = 0
        while pos < len(block):
            take = min(self.rows_per_shard - self._fill, len(block) - pos)
            self._buf[self._fill:self._fill + take] = block[pos:pos + take]
            self._fill += take
            pos += take
            if self._fill == self.rows_per_shard:
                self._flush()
        self._n += len(block)

    def _flush(self) -> None:
        if not self._fill:
            return
        path = os.path.join(self.dir, f"shard_{self._n_shards:05d}.npy")
        np.save(path, self._buf[:self._fill])
        self._n_shards += 1
        self._fill = 0

    def close(self) -> dict:
        self._flush()
        meta = {"n": self._n, "d": self.d, "dtype": self.dtype.name,
                "rows_per_shard": self.rows_per_shard,
                "n_shards": self._n_shards}
        with open(os.path.join(self.dir, META_NAME), "w") as f:
            json.dump(meta, f)
        return meta


class ShardStore:
    """Memory-mapped reader over one party's shard directory.

    `gather_workers` controls the per-shard read pool: shards touched by
    a gather write disjoint output row sets, so they can be read
    concurrently (mmap page faults overlap instead of serializing).
    ``None`` (the default) auto-sizes to ``min(4, cpu_count)`` threads
    and only engages for gathers of at least `_PARALLEL_MIN_ROWS` rows
    spanning 2+ shards; ``0``/``1`` forces sequential; an explicit
    ``>= 2`` forces that pool size regardless of gather size.  The
    threaded path is byte-identical to sequential (pinned by
    `tests/test_streaming_data.py`)."""

    def __init__(self, party_dir: str, *,
                 gather_workers: Optional[int] = None):
        with open(os.path.join(party_dir, META_NAME)) as f:
            meta = json.load(f)
        self.dir = party_dir
        self.n = int(meta["n"])
        self.d = int(meta["d"])
        self.dtype = np.dtype(meta["dtype"])
        self.rows_per_shard = int(meta["rows_per_shard"])
        self.n_shards = int(meta["n_shards"])
        self._maps: list = [None] * self.n_shards
        self.gather_workers = gather_workers
        self._pool: Optional[ThreadPoolExecutor] = None

    @classmethod
    def open(cls, party_dir: str, *,
             gather_workers: Optional[int] = None) -> "ShardStore":
        return cls(party_dir, gather_workers=gather_workers)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.d)

    @property
    def nbytes(self) -> int:
        return self.n * self.d * self.dtype.itemsize

    def _shard(self, s: int) -> np.ndarray:
        m = self._maps[s]
        if m is None:
            path = os.path.join(self.dir, f"shard_{s:05d}.npy")
            m = np.load(path, mmap_mode="r")
            self._maps[s] = m
        return m

    def _pool_for(self, n_rows: int, n_touched: int
                  ) -> Optional[ThreadPoolExecutor]:
        w = self.gather_workers
        if w is not None and w <= 1:
            return None
        if w is None and (n_rows < _PARALLEL_MIN_ROWS or n_touched < 2):
            return None
        if self._pool is None:
            size = min(4, os.cpu_count() or 1) if w is None else int(w)
            if size <= 1:
                return None
            self._pool = ThreadPoolExecutor(
                max_workers=size, thread_name_prefix="shard-gather")
        return self._pool

    def gather(self, rows: np.ndarray) -> np.ndarray:
        """Gather arbitrary rows into a fresh in-RAM array.  Rows are
        grouped per shard (one fancy-index per touched shard) so a
        window gather does a handful of sequential-ish mmap reads
        instead of `len(rows)` random ones.  Per-shard reads land in
        disjoint `out` row sets, so large gathers fan the shards over
        the thread pool (see `gather_workers`) with byte-identical
        results."""
        rows = np.asarray(rows, np.int64).ravel()
        out = np.empty((len(rows), self.d), self.dtype)
        order = np.argsort(rows, kind="stable")
        sr = rows[order]
        sid = sr // self.rows_per_shard
        bounds = np.searchsorted(sid, np.arange(self.n_shards + 1))
        touched = [s for s in range(self.n_shards)
                   if bounds[s] != bounds[s + 1]]

        def read(s: int) -> None:
            lo, hi = bounds[s], bounds[s + 1]
            out[order[lo:hi]] = \
                self._shard(s)[sr[lo:hi] - s * self.rows_per_shard]

        pool = self._pool_for(len(rows), len(touched))
        if pool is None:
            for s in touched:
                read(s)
        else:
            # open maps in the caller's thread (lazy np.load is not
            # guarded), then fan out the disjoint reads
            for s in touched:
                self._shard(s)
            list(pool.map(read, touched))
        return out

    def __getitem__(self, rows) -> np.ndarray:
        return self.gather(rows)

    def __len__(self) -> int:
        return self.n

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None


class ArrayFeatures:
    """In-RAM feature array wrapped as a streaming source.

    Numerically a no-op — gathers hit the underlying ndarray — but its
    presence tells `stage_data` to stage windows instead of device-putting
    the whole block, which is what the streaming-vs-resident parity tests
    and the CI streaming smoke run on (identical bytes, windowed path)."""

    def __init__(self, X: np.ndarray):
        self.X = np.asarray(X)

    @property
    def shape(self):
        return self.X.shape

    @property
    def dtype(self):
        return self.X.dtype

    @property
    def nbytes(self):
        return self.X.nbytes

    def gather(self, rows) -> np.ndarray:
        return self.X[np.asarray(rows, np.int64)]

    __getitem__ = gather

    def __len__(self):
        return self.X.shape[0]


class Permuted:
    """Row-permutation view over another source: ``self[rows] ==
    base[perm[rows]]``.  Applies the PSI alignment (and the train-split
    permutation) without physically reordering shards on disk."""

    def __init__(self, base, perm: np.ndarray):
        self.base = base
        self.perm = np.asarray(perm, np.int64)

    @property
    def shape(self):
        return (len(self.perm), self.base.shape[1])

    @property
    def dtype(self):
        return self.base.dtype

    @property
    def nbytes(self):
        return len(self.perm) * self.base.shape[1] * \
            np.dtype(self.base.dtype).itemsize

    def gather(self, rows) -> np.ndarray:
        rows = np.asarray(rows, np.int64)
        base = self.base
        sub = self.perm[rows]
        return base.gather(sub) if hasattr(base, "gather") else base[sub]

    __getitem__ = gather

    def __len__(self):
        return len(self.perm)


def write_array_shards(party_dir: str, X: np.ndarray, *,
                       rows_per_shard: int = DEFAULT_ROWS_PER_SHARD
                       ) -> ShardStore:
    """Shard an in-RAM array (test helper / small-data migration)."""
    w = ShardWriter(party_dir, X.shape[1], rows_per_shard=rows_per_shard,
                    dtype=X.dtype)
    for lo in range(0, len(X), rows_per_shard):
        w.append(X[lo:lo + rows_per_shard])
    w.close()
    return ShardStore.open(party_dir)
