"""msgpack-based pytree checkpointing (no orbax in this container).

Two surfaces:

* `save`/`restore` — the original flat-leaves format; `restore` needs a
  `like` tree of the same structure (treedef verified by string).
* `save_state`/`restore_state` — structural encoding of an arbitrary
  nested pytree (dicts with str/int keys, lists, tuples/NamedTuples,
  array leaves, scalars, None) WITHOUT needing a `like` template.  This
  is the trainer-state round trip: an engine's `TrainerState`/
  `EventState` (including in-flight ring/buffer content and the epoch
  counter) saves mid-training and restores in a fresh process via
  `engine.load_state(restore_state(path))` — see core.engines.
  NamedTuples come back as plain tuples; `load_state` re-wraps them.
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Optional

import msgpack
import numpy as np
import jax


class CheckpointCorrupt(RuntimeError):
    """The checkpoint file failed integrity verification (truncated,
    bit-flipped, or not a `save_state` file).  Restore refuses to hand
    back a partially-decoded state; failover should fall back to an
    older checkpoint or a fresh start."""


def _pack(obj):
    if isinstance(obj, (np.ndarray, np.generic)) or hasattr(obj, "dtype"):
        a = np.asarray(obj)
        return {b"__nd__": True, b"d": a.tobytes(), b"t": str(a.dtype),
                b"s": list(a.shape)}
    raise TypeError(type(obj))


def _unpack(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"d"], dtype=obj[b"t"]).reshape(obj[b"s"])
    return obj


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "leaves": [np.asarray(l) for l in leaves],
        "treedef": str(treedef),
        "step": step,
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, default=_pack))
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (treedef string is verified)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_unpack,
                                  strict_map_key=False)
    leaves, treedef = jax.tree.flatten(like)
    assert payload["treedef"] == str(treedef), "checkpoint structure mismatch"
    new = payload["leaves"]
    assert len(new) == len(leaves)
    import jax.numpy as jnp
    new = [jnp.asarray(n, dtype=l.dtype).reshape(l.shape)
           for n, l in zip(new, leaves)]
    return jax.tree.unflatten(treedef, new)


# ---------------------------------------------------------------------------
# structural (template-free) trainer-state checkpointing
# ---------------------------------------------------------------------------
_TUP = b"__tup__"


def _encode(node):
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    if isinstance(node, (np.ndarray, np.generic)) or hasattr(node, "dtype"):
        return _pack(node)
    if isinstance(node, dict):
        return {k: _encode(v) for k, v in node.items()}
    if isinstance(node, tuple):          # NamedTuples included
        return {_TUP: [_encode(v) for v in node]}
    if isinstance(node, list):
        return [_encode(v) for v in node]
    raise TypeError(f"unsupported checkpoint node: {type(node)}")


def _decode(node):
    if isinstance(node, dict):
        if b"__nd__" in node:
            return _unpack(node)
        if _TUP in node:
            return tuple(_decode(v) for v in node[_TUP])
        return {k: _decode(v) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode(v) for v in node]
    return node


def save_state(path: str, state: Any, *, step: Optional[int] = None,
               engine: Any = None) -> None:
    """Checkpoint a nested pytree structurally (no `like` template needed
    to restore).  Array leaves keep dtype/shape; tuples (incl.
    NamedTuples) are tagged so `restore_state` rebuilds plain tuples.
    The walk is structural (not jax.tree), so dicts with mixed key
    types survive.

    When `engine` is given, the state is first canonicalized through
    `engine.export_state` — for a mesh-sharded compiled engine this
    strips padding lanes and undoes the slab lane permutation, so the
    on-disk replica order is independent of the device count it was
    written on.  A checkpoint saved on 4 devices then restores on 1 (or
    any other count) via `engine.load_state(restore_state(path))`."""
    exporter = getattr(engine, "export_state", None)
    if exporter is not None:
        state = exporter(state)
    # state-v2: the encoded state+step ride inside one msgpack blob whose
    # crc32 is stored alongside — a torn write or flipped bit anywhere in
    # the blob fails verification instead of decoding into garbage.
    inner = msgpack.packb({"state": _encode(state), "step": step})
    payload = {"fmt": "state-v2", "crc": zlib.crc32(inner), "blob": inner}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)


def _read_state_payload(path: str) -> dict:
    """Read + verify a `save_state` file; the inner {"state","step"}
    dict.  Raises `CheckpointCorrupt` on any integrity failure."""
    with open(path, "rb") as f:
        raw = f.read()
    try:
        payload = msgpack.unpackb(raw, strict_map_key=False)
    except Exception as e:
        raise CheckpointCorrupt(f"{path}: unreadable ({e!r})") from e
    if not isinstance(payload, dict):
        raise CheckpointCorrupt(f"{path}: not a save_state checkpoint")
    fmt = payload.get("fmt")
    if fmt == "state-v2":
        blob, crc = payload.get("blob"), payload.get("crc")
        if not isinstance(blob, bytes) or zlib.crc32(blob) != crc:
            raise CheckpointCorrupt(f"{path}: checksum mismatch")
        try:
            return msgpack.unpackb(blob, strict_map_key=False)
        except Exception as e:
            raise CheckpointCorrupt(f"{path}: blob undecodable "
                                    f"({e!r})") from e
    if fmt == "state-v1":         # pre-checksum files stay restorable
        return payload
    raise CheckpointCorrupt(f"{path}: not a save_state checkpoint "
                            f"(fmt={fmt!r})")


def restore_state(path: str) -> Any:
    """Inverse of `save_state`: the nested structure with numpy leaves.
    Feed it to `engine.load_state(...)` to re-wrap engine state types.
    Raises `CheckpointCorrupt` if the file fails crc verification."""
    return _decode(_read_state_payload(path)["state"])


def load_step(path: str) -> Optional[int]:
    with open(path, "rb") as f:
        head = msgpack.unpackb(f.read(), object_hook=_unpack,
                               strict_map_key=False)
    if isinstance(head, dict) and head.get("fmt") == "state-v2":
        return _read_state_payload(path).get("step")
    return head.get("step")
