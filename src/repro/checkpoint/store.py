"""msgpack-based pytree checkpointing (no orbax in this container)."""
from __future__ import annotations

import os
from typing import Any, Optional

import msgpack
import numpy as np
import jax


def _pack(obj):
    if isinstance(obj, (np.ndarray, np.generic)) or hasattr(obj, "dtype"):
        a = np.asarray(obj)
        return {b"__nd__": True, b"d": a.tobytes(), b"t": str(a.dtype),
                b"s": list(a.shape)}
    raise TypeError(type(obj))


def _unpack(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"d"], dtype=obj[b"t"]).reshape(obj[b"s"])
    return obj


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "leaves": [np.asarray(l) for l in leaves],
        "treedef": str(treedef),
        "step": step,
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, default=_pack))
    os.replace(tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restore into the structure of `like` (treedef string is verified)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_unpack,
                                  strict_map_key=False)
    leaves, treedef = jax.tree.flatten(like)
    assert payload["treedef"] == str(treedef), "checkpoint structure mismatch"
    new = payload["leaves"]
    assert len(new) == len(leaves)
    import jax.numpy as jnp
    new = [jnp.asarray(n, dtype=l.dtype).reshape(l.shape)
           for n, l in zip(new, leaves)]
    return jax.tree.unflatten(treedef, new)


def load_step(path: str) -> Optional[int]:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_unpack,
                                  strict_map_key=False)
    return payload.get("step")
