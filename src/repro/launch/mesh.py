"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.  Single-pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; in PubSub-VFL the two
pods map to the two parties (DESIGN.md §3) and the only pod-crossing
traffic is the cut-layer embedding/gradient channels.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Degenerate mesh for CPU smoke tests (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
