"""End-to-end training driver.

Two modes:
  --mode vfl   : the paper's system — PubSub-VFL (or any baseline) on a
                 tabular dataset with the DES runtime + real JAX updates.
  --mode lm    : train a reduced assigned architecture for a few hundred
                 steps on CPU (synthetic token streams) through the
                 SplitModel path — proves the backbone substrate trains.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode vfl --method pubsub \
      --dataset bank --epochs 5
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen2-0.5b \
      --steps 200
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def run_vfl(args) -> None:
    from repro.core.runtime import ExperimentConfig, run_experiment
    cfg = ExperimentConfig(
        method=args.method, dataset=args.dataset, n_epochs=args.epochs,
        scale=args.scale, batch_size=args.batch_size, w_a=args.w_a,
        w_p=args.w_p, use_planner=args.plan, dp_mu=args.dp_mu,
        seed=args.seed)
    res = run_experiment(cfg)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("history", "losses")}, default=str,
                     indent=2))
    print("history:", [round(h, 4) for h in res["history"]])


def run_lm(args) -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.steps import make_model, make_train_step
    from repro.checkpoint.store import save

    cfg = get_config(args.arch).reduced()
    model = make_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    opt, train_step = make_train_step(model, lr=args.lr,
                                      dp_sigma=args.dp_sigma,
                                      dp_clip=1.0 if args.dp_sigma else 1e9)
    opt_state = opt.init(params)
    step_fn = jax.jit(train_step)

    B, S = args.batch, args.seq
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for step in range(args.steps):
        key, sub = jax.random.split(key)
        toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1))
        if cfg.frontend == "audio_frames":
            batch = {"tokens_p": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(toks[:, :S], jnp.int32)}
        else:
            batch = {"tokens_p": jnp.asarray(toks[:, :S], jnp.int32),
                     "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        batch["x_a"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_active)),
                                   jnp.float32)
        if cfg.frontend == "vision_patches":
            n_vis = max(1, S // 4)
            batch["tokens_p"] = batch["tokens_p"][:, :S - n_vis]
            batch["labels"] = batch["labels"][:, :S - n_vis]
            batch["patches_p"] = jnp.asarray(
                rng.normal(size=(B, n_vis, cfg.d_model)), jnp.float32)
        params, opt_state, loss = step_fn(params, opt_state, batch, sub)
        if step % max(args.steps // 10, 1) == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    if args.ckpt:
        save(args.ckpt, params, step=args.steps)
        print("saved checkpoint to", args.ckpt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["vfl", "lm"], default="vfl")
    # vfl
    ap.add_argument("--method", default="pubsub")
    ap.add_argument("--dataset", default="bank")
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--w-a", type=int, default=8)
    ap.add_argument("--w-p", type=int, default=10)
    ap.add_argument("--plan", action="store_true")
    ap.add_argument("--dp-mu", type=float, default=float("inf"))
    # lm
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dp-sigma", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (run_vfl if args.mode == "vfl" else run_lm)(args)


if __name__ == "__main__":
    main()
