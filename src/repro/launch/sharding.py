"""Partition rules: param/activation PartitionSpecs for the production mesh.

Megatron-style tensor parallelism over the "model" axis:
  - attention q/k/v projections, FFN up/gate, RG-LRU/RWKV input projections,
    LM head: column-sharded (last dim over "model")
  - attention output, FFN down, recurrent output: row-sharded
  - MoE expert weights: expert-parallel (expert dim over "model")
  - embeddings: vocab-sharded
Batch/activations shard over "data" (and "pod" when multi-pod).  A dim is
sharded only if divisible by the axis size (e.g. hubert's 504-way head
stays replicated).  `zero=True` additionally shards optimizer moments over
"data" (ZeRO-1) — a §Perf hillclimb lever.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# last-dim ("column") sharded weights
_COL = {"wq", "wk", "wv", "wg", "wu", "wy", "wx", "wa", "wi", "head",
        "w_uk", "w_uv", "conv_w"}
# first-dim ("row") sharded weights
_ROW = {"wo", "wd"}
# sharded vectors (outputs of column-sharded projections)
_VEC = {"bq", "bk", "bv", "conv_b", "lam"}
_EMBED = {"embed"}


def _spec_for(name: str, rank: int, stacked: bool) -> Tuple:
    base_rank = rank - (1 if stacked else 0)
    spec: list = [None] * base_rank
    if name in _EMBED and base_rank == 2:
        spec[0] = "model"                      # vocab-sharded
    elif base_rank == 3 and name in ("wg", "wu", "wd"):
        spec[0] = "model"                      # expert-parallel MoE
    elif name in _COL and base_rank >= 2:
        spec[-1] = "model"
    elif name in _ROW and base_rank == 2:
        spec[0] = "model"
    elif name in _VEC and base_rank == 1:
        spec[0] = "model"
    elif name == "u" and base_rank == 2:
        spec[0] = "model"                      # wkv u: heads over model
    if stacked:
        spec = [None] + spec
    return tuple(spec)


def _fit_divisibility(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh
                      ) -> P:
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def params_sharding(params, mesh: Mesh, *, zero: bool = False,
                    data_axes: Tuple[str, ...] = ("data",)):
    """NamedSharding pytree matching `params` (works for opt moments too
    since they mirror the param tree)."""
    def walk(node, stacked: bool, name: str):
        if isinstance(node, dict):
            return {k: walk(v, stacked or k in ("bottom", "top"), k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(walk(v, stacked, name) for v in node)
        # leaf
        spec = _spec_for(name, np.ndim(node), stacked)
        pspec = _fit_divisibility(spec, np.shape(node), mesh)
        if zero:
            pspec = _apply_zero(pspec, np.shape(node), mesh, data_axes)
        return NamedSharding(mesh, pspec)

    return walk(params, False, "")


def _apply_zero(pspec: P, shape, mesh: Mesh, data_axes) -> P:
    """ZeRO: also shard the largest unsharded dim over the data axes."""
    size = int(np.prod([mesh.shape[a] for a in data_axes]))
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    best, best_dim = None, 0
    for i, (d, ax) in enumerate(zip(shape, spec)):
        if ax is None and d % size == 0 and d > best_dim:
            best, best_dim = i, d
        if ax is not None and not isinstance(ax, tuple):
            pass
    if best is not None and best_dim >= size:
        ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
        spec[best] = ax
    return P(*spec)


def batch_sharding(tree, mesh: Mesh,
                   data_axes: Tuple[str, ...] = ("data",)):
    """Shard the leading (batch) dim of every input leaf over data axes."""
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)

    def leaf(x):
        shape = x.shape
        size = int(np.prod([mesh.shape[a] for a in data_axes]))
        if len(shape) >= 1 and shape[0] % size == 0:
            return NamedSharding(mesh, P(ax, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf, tree)


def cache_sharding(cache, mesh: Mesh,
                   data_axes: Tuple[str, ...] = ("data",)):
    """KV/recurrent caches: batch dim over "data", feature (last) dim over
    "model" — head_dim/latent-rank sharding keeps 32k-500k decode caches
    within per-chip HBM (attention contracts over the sharded dim, which
    XLA lowers to a reduce-scatter/all-reduce).  Stacked stage caches have
    a leading layer axis, then batch; scalars replicate."""
    ax = data_axes[0] if len(data_axes) == 1 else tuple(data_axes)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape.get("model", 1)

    def leaf(x):
        shape = x.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        # batch dim: first (unstacked) or second (stacked stage cache)
        for bdim in ((1, 0) if len(shape) > 1 else (0,)):
            if shape[bdim] % dsize == 0 and shape[bdim] > 1:
                spec[bdim] = ax
                break
        # feature dim: last, over model (never the batch dim)
        last = len(shape) - 1
        if spec[last] is None and len(shape) >= 3 and \
                shape[last] % msize == 0 and shape[last] >= msize:
            spec[last] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache)
