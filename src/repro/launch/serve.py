"""Split-inference serving CLI — thin wrapper over `repro.serve`.

The passive party's bottom stack and the active party's top stack run as
one jitted slot-batched decode step; the PubSub channels carry the cut
activations between pods in deployment.  Two modes:

one-shot (legacy):  decode a fixed set of requests and exit
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 16 --gen 32

open-loop:          Poisson arrivals at --load QPS through the
                    continuous-batching scheduler
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-9b \
      --load 16 --requests 32 --slots 8 --gen 16

Robustness knobs (docs/architecture.md §Robustness & overload):
``--deadline S`` sheds/preempts requests past their latency budget,
``--queue-cap N`` bounds the backlog (``--queue-policy reject|block``),
and ``--crash-step K`` injects a fatal engine crash at scheduler step K
— served through `run_with_recovery`, which rebuilds the engine and
replays the in-flight requests token-for-token:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --load 32 --requests 24 --deadline 2.0 --queue-cap 8 \
      --crash-step 12
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.serve import (Completion, Request, ServeEngine, ServeFaultPlan,
                         open_loop, run_with_recovery, synthetic_requests)


def _parse_prompt(spec: str) -> List[int]:
    return [int(t) for t in spec.replace(",", " ").split()]


def build_requests(args, vocab_size: int) -> List[Request]:
    if args.prompt:
        toks = _parse_prompt(args.prompt)
        return [Request(prompt=toks, max_new_tokens=args.gen,
                        temperature=args.temperature, seed=args.seed + i,
                        deadline_s=args.deadline)
                for i in range(args.batch)]
    # seeded synthetic prompts — drawn ONCE per request and consumed for
    # real during prefill (the first sampled token conditions on them)
    n = args.requests if args.load else args.batch
    return synthetic_requests(
        n, vocab_size, seed=args.seed,
        prompt_lens=(args.prompt_len, args.prompt_len),
        max_new_tokens=args.gen, temperature=args.temperature,
        deadline_s=args.deadline)


def main(argv: Optional[Sequence[str]] = None) -> List[Completion]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of one-shot requests (legacy mode)")
    ap.add_argument("--slots", type=int, default=None,
                    help="slot count (default: --batch one-shot, 8 open-loop)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--prompt", default=None,
                    help="explicit prompt token ids, e.g. '5,3,17'")
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-cap", type=int, default=None,
                    help="per-slot cache capacity "
                         "(default: prompt-len + gen)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--load", type=float, default=None,
                    help="open-loop mode: offered Poisson QPS")
    ap.add_argument("--requests", type=int, default=32,
                    help="open-loop request count")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request latency budget in seconds "
                         "(expired requests shed/preempted)")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bound the request queue (admission control)")
    ap.add_argument("--queue-policy", choices=("reject", "block"),
                    default="reject")
    ap.add_argument("--crash-step", type=int, default=None,
                    help="inject a fatal engine crash at this scheduler "
                         "step; served under run_with_recovery")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")

    requests = build_requests(args, cfg.vocab_size)
    plen_max = max(r.prompt.size for r in requests)
    cap = args.cache_cap or (plen_max + args.gen)
    slots = args.slots or (8 if args.load else args.batch)
    faults = (ServeFaultPlan(crashes=(args.crash_step,))
              if args.crash_step is not None else None)
    engine = ServeEngine(cfg, slots=slots, cache_cap=cap, seed=args.seed,
                         faults=faults)
    recover = faults is not None

    t0 = time.time()
    events: dict = {}
    if args.load:
        queue = engine.queue(capacity=args.queue_cap,
                             policy=args.queue_policy)
        done = open_loop(engine, requests, args.load, seed=args.seed,
                         queue=queue, recover=recover, events=events)
    elif recover:
        queue = engine.queue()
        for r in requests:
            queue.submit(r)
        queue.close()
        res = run_with_recovery(engine, queue)
        events["restarts"] = res.restarts
        done = res.completions
    else:
        done = engine.serve(requests)
    dt = time.time() - t0

    stats = engine.last_run_stats
    ok = [c for c in done if c.ok]
    n_tok = sum(len(c.tokens) for c in done)
    print(f"arch={cfg.name} slots={slots} requests={len(done)} "
          f"gen_tokens={n_tok} {n_tok / dt:.1f} tok/s "
          f"occupancy={stats['occupancy']:.2f} "
          f"decode_compiles={stats['decode_compiles']}")
    if ok:
        ttft = np.asarray([c.ttft_s for c in ok])
        print(f"ttft p50={np.percentile(ttft, 50) * 1e3:.1f}ms "
              f"p99={np.percentile(ttft, 99) * 1e3:.1f}ms")
    reasons: dict = {}
    for c in done:
        reasons[c.finish_reason] = reasons.get(c.finish_reason, 0) + 1
    if events or len(reasons) > 1:
        parts = [f"{k}:{v}" for k, v in sorted(reasons.items())]
        if "rejected" in events:
            parts.append(f"queue_rejected:{events['rejected']}")
        if "restarts" in events:
            parts.append(f"restarts:{events['restarts']}")
        print("robustness:", " ".join(parts))
    print("sample:", done[0].tokens[:16] if done else [])
    return done


if __name__ == "__main__":
    main()
