"""Split-inference serving driver: batched decode with per-party caches.

The passive party's bottom stack and the active party's top stack run as
one jitted decode step (the dry-run proves the joint graph lowers); the
PubSub channels carry the cut activations between pods in deployment.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    model = make_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    decode = jax.jit(make_decode_step(model))

    B = args.batch
    cap = args.prompt_len + args.gen
    cache = model.init_cache(B, cap)
    rng = np.random.default_rng(args.seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 1)),
                      jnp.int32)
    xa = jnp.zeros((B, 1, cfg.d_active), jnp.float32)

    # prefill token-by-token (reduced model; exercises the cache path)
    t0 = time.time()
    for i in range(args.prompt_len):
        tok_in = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, 1)),
                             jnp.int32)
        logits, cache = decode(params, {"tokens_p": tok_in, "x_a": xa},
                               cache)
    out_tokens = []
    for i in range(args.gen):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, {"tokens_p": tok, "x_a": xa}, cache)
    dt = time.time() - t0
    total = args.prompt_len + args.gen
    print(f"arch={cfg.name} batch={B} steps={total} "
          f"{B * total / dt:.1f} tok/s (CPU, reduced config)")
    print("sample:", np.stack(out_tokens, 1)[0][:16].tolist())


if __name__ == "__main__":
    main()
