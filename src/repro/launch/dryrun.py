import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  512 placeholder host devices back the production
# meshes: (16,16)=256 chips single-pod, (2,16,16)=512 chips multi-pod.
"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) and
emit memory/cost/collective analysis for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k \
      --mesh single [--zero] [--no-remat] [--out runs/dryrun.jsonl]
  python -m repro.launch.dryrun --all --out runs/dryrun.jsonl  # resumable
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED, SHAPES, get_config, input_specs,
                           long_context_variant, shape_applicability)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_sharding, cache_sharding,
                                   params_sharding)
from repro.launch.steps import (make_decode_step, make_model,
                                make_prefill_step, make_train_step)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"=\s*(\(?)([a-z0-9\[\],{} ]+?)\s+"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute)", re.I)
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all `dtype[d0,d1,...]` shapes in `text`."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the optimized HLO
    (per-device; ICI roofline proxy — cost_analysis has no collective
    field)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "-done(" in ls:
            continue            # async pair: count only the -start op
        hit = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", ls):
                hit = c
                break
        if hit is None:
            continue
        lhs = ls.split("=", 1)[0] if "=" in ls else ""
        rhs = ls.split("=", 1)[1] if "=" in ls else ls
        shape_part = rhs.split(hit)[0]
        b = _shape_bytes(shape_part)
        if b:
            out[hit] += b
            out["count"] += 1
    return out


def _mem_report(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:            # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {"unavailable": True}
    rep = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            rep[attr] = int(v)
    if not rep:
        rep["repr"] = str(ma)
    return rep


def _cost_report(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:            # pragma: no cover
        return {"error": str(e)}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower()
                or k in ("transcendentals",))}


def _probe_stage(cfg, stage, shape, mesh, data_axes, kind: str) -> Dict:
    """HLO flops/bytes/collectives of ONE layer-group of `stage`, compiled
    under the production sharding.

    XLA's cost_analysis counts a lax.scan body ONCE (trip counts are not
    multiplied), so per-(arch x shape) totals are reconstructed as
      corrected = reported + sum_i (repeat_i - 1) * body_i
    where body_i comes from this probe (embedding/head/optimizer terms are
    outside the scans and therefore already fully counted)."""
    import functools

    from repro.models import blocks
    repeat, pattern = stage
    B = shape.global_batch
    S = 1 if kind == "decode" else shape.seq_len
    act = jnp.dtype(cfg.dtype)
    x_sds = jax.ShapeDtypeStruct((B, S, cfg.d_model), act)
    if cfg.mrope:
        pos_sds = jax.ShapeDtypeStruct((3, B, S), jnp.dtype("int32"))
    else:
        pos_sds = jax.ShapeDtypeStruct((B, S), jnp.dtype("int32"))

    def init_group(key):
        ks = jax.random.split(key, len(pattern))
        return tuple(blocks.init_layer(k, cfg, spec)
                     for k, spec in zip(ks, pattern))

    params_sds = jax.eval_shape(init_group, jax.random.PRNGKey(0))
    from repro.launch.sharding import (batch_sharding, cache_sharding,
                                       params_sharding)
    # group params have no stack axis -> plain (unstacked) rules
    p_shard = params_sharding(params_sds, mesh, data_axes=data_axes)
    x_shard = batch_sharding({"x": x_sds, "pos": pos_sds}, mesh,
                             data_axes=data_axes)

    cache_sds = None
    c_shard = None
    if kind == "decode":
        def init_group_cache():
            return tuple(blocks.init_layer_cache(cfg, spec, B,
                                                 shape.seq_len)
                         for spec in pattern)
        cache_sds = jax.eval_shape(init_group_cache)
        c_shard = cache_sharding(cache_sds, mesh, data_axes=data_axes)

    def fwd(params, x, positions, cache):
        for i, spec in enumerate(pattern):
            c = None if cache is None else cache[i]
            x, _, _ = blocks.apply_layer(params[i], cfg, spec, x,
                                         positions, c)
        return x

    with mesh:
        if kind == "train":
            def body(params, x, positions):
                f = fwd
                if cfg.remat:
                    f = jax.checkpoint(f)
                y = f(params, x, positions, None)
                return jnp.sum(y.astype(jnp.float32))

            fn = jax.jit(jax.value_and_grad(body, argnums=(0, 1)),
                         in_shardings=(p_shard, x_shard["x"],
                                       x_shard["pos"]))
            compiled = fn.lower(params_sds, x_sds, pos_sds).compile()
        else:
            fn = jax.jit(fwd, in_shardings=(p_shard, x_shard["x"],
                                            x_shard["pos"], c_shard))
            compiled = fn.lower(params_sds, x_sds, pos_sds,
                                cache_sds).compile()
    cost = _cost_report(compiled)
    return {
        "repeat": repeat,
        "pattern": [list(p) for p in pattern],
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "collectives": collective_bytes(compiled.as_text()),
    }


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            zero: bool = False, remat: bool = True,
            dp_sigma: float = 0.0, opts: Optional[Dict] = None) -> Dict:
    """opts: beyond-paper §Perf levers applied to the config, e.g.
    {"ce_chunk": 2048, "remat_policy": "dots", "moe_dispatch_i8": True}."""
    opts = dict(opts or {})
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    runnable, note = shape_applicability(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "zero": zero, "remat": remat, "note": note,
           "opts": opts}
    if not runnable:
        rec["status"] = "skipped"
        return rec
    if shape_name == "long_500k":
        cfg = long_context_variant(cfg)
    cfg = cfg.replace(dtype="bfloat16", param_dtype="bfloat16",
                      remat=(remat and shape.kind == "train"), **opts)

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    data_axes = ("pod", "data") if multi else ("data",)

    model = make_model(cfg)
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(model.init, key)
    p_shard = params_sharding(params_shapes, mesh, zero=False,
                              data_axes=data_axes)
    specs = input_specs(cfg, shape)
    b_shard = batch_sharding(specs, mesh, data_axes=data_axes)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            opt, train_step = make_train_step(model, dp_sigma=dp_sigma)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            o_shard = params_sharding(opt_shapes, mesh, zero=zero,
                                      data_axes=data_axes)
            rng_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
            fn = jax.jit(
                train_step,
                in_shardings=(p_shard, o_shard, b_shard, None),
                out_shardings=(p_shard, o_shard, None))
            lowered = fn.lower(params_shapes, opt_shapes, specs, rng_s)
        else:
            capacity = shape.seq_len
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, capacity))
            c_shard = cache_sharding(cache_shapes, mesh,
                                     data_axes=data_axes)
            if shape.kind == "prefill":
                step = make_prefill_step(model)
            else:
                step = make_decode_step(model)
            fn = jax.jit(step, in_shardings=(p_shard, b_shard, c_shard),
                         out_shardings=(None, c_shard))
            lowered = fn.lower(params_shapes, specs, cache_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # per-stage body probes -> scan-trip-count correction (see _probe_stage)
    stages = list(model.bottom_stages) + list(model.top_stages)
    probes = []
    for stage in stages:
        try:
            probes.append(_probe_stage(cfg, stage, shape, mesh, data_axes,
                                       shape.kind))
        except Exception as e:       # pragma: no cover
            probes.append({"repeat": stage[0], "error": str(e)})

    cost = _cost_report(compiled)
    coll = collective_bytes(compiled.as_text())
    extra_flops = sum((p["repeat"] - 1) * p.get("flops", 0.0)
                      for p in probes)
    extra_bytes = sum((p["repeat"] - 1) * p.get("bytes", 0.0)
                      for p in probes)
    extra_coll = {}
    for key in _COLLECTIVES:
        extra_coll[key] = coll.get(key, 0) + sum(
            (p["repeat"] - 1) * p.get("collectives", {}).get(key, 0)
            for p in probes)

    rec.update(
        status="ok", lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=_mem_report(compiled),
        cost=cost,
        collectives=coll,
        corrected_flops=cost.get("flops", 0.0) + extra_flops,
        corrected_bytes=cost.get("bytes accessed", 0.0) + extra_bytes,
        corrected_collectives=extra_coll,
        stage_probes=probes,
        n_params=cfg.param_count(),
        n_active_params=cfg.active_param_count(),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", action="append", default=[],
                    help="k=v config override, e.g. ce_chunk=2048")
    args = ap.parse_args()
    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        opts[k] = (int(v) if v.lstrip("-").isdigit()
                   else v == "true" if v in ("true", "false") else v)

    combos = []
    if args.all:
        for mesh_kind in ("single", "multi"):
            for arch in ASSIGNED:
                for shape in SHAPES:
                    combos.append((arch, shape, mesh_kind))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, args.mesh)]

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  r.get("zero", False)))
                except json.JSONDecodeError:
                    pass

    for arch, shape, mesh_kind in combos:
        key = (arch, shape, mesh_kind, args.zero)
        if key in done:
            print(f"[skip-done] {key}")
            continue
        print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
        try:
            rec = run_one(arch, shape, mesh_kind, zero=args.zero,
                          remat=not args.no_remat, opts=opts)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "zero": args.zero, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        line = json.dumps(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
        print(line[:400], flush=True)


if __name__ == "__main__":
    main()
