"""jit-able train / prefill / decode steps for any assigned architecture."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import SplitModel
from repro.optim.optimizers import adam, apply_updates


def make_model(cfg: ArchConfig) -> SplitModel:
    return SplitModel(cfg)


def make_train_step(model: SplitModel, lr: float = 3e-4,
                    dp_sigma: float = 0.0, dp_clip: float = 1e9):
    opt = adam(lr)

    def train_step(params, opt_state, batch, rng):
        def loss_fn(p):
            return model.loss(p, batch, dp_sigma=dp_sigma, dp_clip=dp_clip,
                              rng=rng)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        ups, opt_state2 = opt.update(grads, opt_state, params)
        params2 = apply_updates(params, ups)
        return params2, opt_state2, loss

    return opt, train_step


def make_prefill_step(model: SplitModel):
    def prefill_step(params, batch, cache):
        logits, cache, _ = model.forward(params, batch, cache=cache)
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(model: SplitModel):
    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)

    return decode_step
