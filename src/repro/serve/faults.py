"""Declarative fault plans for the serving scheduler — `core/faults.py`'s
twin on the inference side.

A :class:`ServeFaultPlan` describes *what goes wrong* while the engine
serves: scheduler-step stalls and straggler drift (wall-clock latency
injected before the compiled step), transient step failures (the step
"fails" once and is retried — same inputs, same compiled program, so
the retry is bitwise the step that should have run), fatal engine
crashes (the in-memory slot caches are lost; only
`engine.run_with_recovery` brings the requests back), and poisoned
requests (admission blows up for a specific rid).

Everything is indexed by the engine's **scheduler step counter** or a
request's **rid**, never by wall-clock time or a host RNG — so a plan
replays identically under the run seed, which is what lets
`benchmarks/serve_chaos.py` assert token-for-token replay parity across
a crash.  One-shot faults (step failures, crashes) fire once per plan
instance: a recovered engine sharing the plan does not re-crash at the
same step, mirroring `api.callbacks.Watchdog`'s `_fired` discipline.
Crashes are consumed in tuple order against each engine incarnation's
own step counter, so ``crashes=(10, 30)`` means "first engine dies at
step 10, its replacement dies at step 30".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Set, Tuple


class InjectedStepFailure(RuntimeError):
    """Transient failure of one scheduler step.  The scheduler retries
    the step (inputs untouched — nothing was mutated), so a plan with
    step failures still produces bit-identical output."""

    def __init__(self, step: int):
        super().__init__(f"injected transient step failure at step {step}")
        self.step = step


class InjectedCrash(RuntimeError):
    """Fatal engine crash: slot caches and in-flight decode state are
    gone.  `ServeEngine.run` wraps this (like any other scheduler-loop
    exception) in `EngineCrashed` after re-queueing the in-flight
    requests for replay."""

    def __init__(self, step: int):
        super().__init__(f"injected engine crash at step {step}")
        self.step = step


@dataclass(frozen=True)
class StepStall:
    """One-off stall: the scheduler sleeps `stall_s` seconds before
    executing step `at_step` (an operator pause, a GC spike, a
    preempted VM — anything that stops the world once)."""
    at_step: int
    stall_s: float


@dataclass(frozen=True)
class StragglerDrift:
    """Cadence drift: every step >= `start_step` pays an extra
    ``min(cap_s, (step - start_step) * per_step_s)`` seconds — the
    serving-side analogue of `core.faults.StragglerFault`'s ramp (a
    slowly degrading accelerator or a noisy neighbour)."""
    start_step: int = 0
    per_step_s: float = 0.0
    cap_s: float = math.inf


@dataclass
class ServeFaultPlan:
    """The full failure scenario of one serving run.

    stalls       one-off `StepStall`s
    drift        optional `StragglerDrift`
    step_fails   step indices that fail transiently once (retried)
    crashes      engine-lifetime step indices that kill the engine, one
                 per engine incarnation, consumed in order
    poison_rids  rids whose admission fails (the request *looks* valid
                 at submit but breaks the engine-side admit — only that
                 request's future fails, serving continues)
    """
    stalls: Tuple[StepStall, ...] = ()
    drift: Optional[StragglerDrift] = None
    step_fails: Tuple[int, ...] = ()
    crashes: Tuple[int, ...] = ()
    poison_rids: Tuple[int, ...] = ()

    # one-shot bookkeeping (never serialized, never compared)
    _fired_fails: Set[int] = field(default_factory=set, repr=False,
                                   compare=False)
    _crashes_taken: int = field(default=0, repr=False, compare=False)

    def __post_init__(self):
        self.stalls = tuple(self.stalls)
        self.step_fails = tuple(self.step_fails)
        self.crashes = tuple(self.crashes)
        self.poison_rids = tuple(self.poison_rids)
        for s in self.stalls:
            if s.at_step < 0 or s.stall_s < 0:
                raise ValueError("StepStall needs at_step >= 0, "
                                 "stall_s >= 0")
        if self.drift is not None:
            d = self.drift
            if d.start_step < 0 or d.per_step_s < 0 or d.cap_s < 0:
                raise ValueError("StragglerDrift fields must be >= 0")
        if any(k < 0 for k in self.step_fails + self.crashes):
            raise ValueError("step indices must be >= 0")
        if any(r < 0 for r in self.poison_rids):
            raise ValueError("poison rids must be >= 0")

    @property
    def empty(self) -> bool:
        return not (self.stalls or self.step_fails or self.crashes
                    or self.poison_rids
                    or (self.drift is not None
                        and self.drift.per_step_s > 0))

    # -- scheduler-side hooks ------------------------------------------
    def stall_s_at(self, step: int) -> float:
        """Injected latency before `step` runs (stalls + drift)."""
        dt = sum(s.stall_s for s in self.stalls if s.at_step == step)
        if self.drift is not None and step >= self.drift.start_step:
            dt += min(self.drift.cap_s,
                      (step - self.drift.start_step)
                      * self.drift.per_step_s)
        return dt

    def take_step_failure(self, step: int) -> bool:
        """True exactly once for each step index in `step_fails`."""
        if step in self.step_fails and step not in self._fired_fails:
            self._fired_fails.add(step)
            return True
        return False

    def maybe_crash(self, step: int) -> None:
        """Raise `InjectedCrash` when this engine incarnation's step
        counter reaches the next unconsumed crash index."""
        if self._crashes_taken >= len(self.crashes):
            return
        at = self.crashes[self._crashes_taken]
        if step >= at:
            self._crashes_taken += 1
            raise InjectedCrash(step)

    def poisoned(self, rid: int) -> bool:
        return rid in self.poison_rids

    # -- JSON round trip (benchmarks, CLI) ------------------------------
    def to_dict(self) -> Dict:
        return {
            "stalls": [s.__dict__.copy() for s in self.stalls],
            "drift": (None if self.drift is None
                      else self.drift.__dict__.copy()),
            "step_fails": list(self.step_fails),
            "crashes": list(self.crashes),
            "poison_rids": list(self.poison_rids),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "ServeFaultPlan":
        drift = d.get("drift")
        return cls(
            stalls=tuple(StepStall(**s) for s in d.get("stalls", ())),
            drift=None if drift is None else StragglerDrift(**drift),
            step_fails=tuple(d.get("step_fails", ())),
            crashes=tuple(d.get("crashes", ())),
            poison_rids=tuple(d.get("poison_rids", ())))
