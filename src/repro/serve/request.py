"""Requests, completions, and the thread-safe request queue.

A :class:`Request` is what a client hands the serving engine: a real
prompt (token ids for the passive party), the active party's private
feature vector ``x_a``, per-request sampling params (runtime scalars of
the compiled slot program — never a recompile), and stop conditions.
``RequestQueue.submit`` stamps the arrival time and returns a
:class:`concurrent.futures.Future` that resolves to a
:class:`Completion` when the scheduler evicts the finished slot.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    """One generation request against the split model.

    prompt          passive-party token ids, length >= 1 (consumed for
                    real during prefill — the slot's first ``len(prompt)``
                    steps feed these tokens into the cache)
    max_new_tokens  decode budget; the slot is evicted when reached
    temperature     0.0 = greedy argmax; > 0 = categorical sampling
    seed            per-request sampling key (counter-based jax.random)
    eos_id          optional stop token; eviction includes it in the output
    x_a             active party's private feature vector (d_active,);
                    zeros when omitted
    """
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_id: Optional[int] = None
    x_a: Optional[np.ndarray] = None

    # stamped by RequestQueue.submit
    rid: int = -1
    t_submit: float = 0.0
    future: Optional[Future] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class Completion:
    """Resolved output of one request, with the latency breakdown the
    load benchmark aggregates (TTFT = t_first - t_submit)."""
    rid: int
    prompt_len: int
    tokens: List[int]
    t_submit: float
    t_admit: float
    t_first: float
    t_done: float
    finish_reason: str = "length"          # "length" | "eos"

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def decode_s(self) -> float:
        return self.t_done - self.t_first

    @property
    def per_token_s(self) -> float:
        """Mean inter-token latency after the first token."""
        n = len(self.tokens)
        return self.decode_s / (n - 1) if n > 1 else 0.0


class RequestQueue:
    """Thread-safe FIFO between producers (clients / the load generator)
    and the single scheduler thread.  Producers ``submit``; the scheduler
    ``try_get``s without blocking while slots are busy and ``wait``s when
    idle.  ``close`` ends the stream: the scheduler drains what is left
    and returns."""

    def __init__(self):
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._next_rid = 0

    def submit(self, req: Request) -> Future:
        with self._cv:
            if self._closed:
                raise RuntimeError("queue is closed")
            req.rid = self._next_rid
            self._next_rid += 1
            req.t_submit = time.perf_counter()
            req.future = Future()
            self._q.append(req)
            self._cv.notify()
        return req.future

    def try_get(self) -> Optional[Request]:
        with self._cv:
            return self._q.popleft() if self._q else None

    def wait(self, timeout: float) -> None:
        """Block until something is queued, the queue closes, or timeout."""
        with self._cv:
            if not self._q and not self._closed:
                self._cv.wait(timeout)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def empty(self) -> bool:
        with self._cv:
            return not self._q

    def __len__(self) -> int:
        with self._cv:
            return len(self._q)
