"""Requests, completions, and the thread-safe bounded request queue.

A :class:`Request` is what a client hands the serving engine: a real
prompt (token ids for the passive party), the active party's private
feature vector ``x_a``, per-request sampling params (runtime scalars of
the compiled slot program — never a recompile), stop conditions, and an
optional ``deadline_s`` latency budget.  ``RequestQueue.submit`` stamps
the arrival time and returns a :class:`concurrent.futures.Future` that
resolves to a :class:`Completion` when the scheduler evicts the
finished slot.

Robustness contract (docs/architecture.md §Robustness & overload):

* the queue is optionally **bounded** — ``RequestQueue(capacity=N,
  policy="reject")`` raises :class:`QueueFull` at submit when the
  backlog is at capacity, ``policy="block"`` parks the producer until a
  slot frees or the queue closes;
* every completion carries a ``finish_reason`` from the closed taxonomy
  ``"length" | "eos" | "expired" | "aborted" | "error"`` — a client
  checks :attr:`Completion.ok` instead of parsing strings;
* a future handed out by ``submit`` is ALWAYS resolved, whatever the
  scheduler does — normal eviction, deadline expiry, abort, per-request
  validation failure, or engine crash (``set_exception``).  The
  ``resolve_future`` / ``fail_future`` helpers make resolution
  idempotent so racing exit paths never raise ``InvalidStateError``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

FINISH_REASONS = ("length", "eos", "expired", "aborted", "error")


class QueueClosed(RuntimeError):
    """Submit against a closed queue (also raised to producers parked on
    a full ``policy="block"`` queue when it closes under them)."""


class QueueFull(RuntimeError):
    """Submit against a bounded queue at capacity under
    ``policy="reject"`` — the admission-control signal a client backs
    off on."""

    def __init__(self, capacity: int):
        super().__init__(f"request queue is at capacity ({capacity})")
        self.capacity = capacity


class RequestRejected(ValueError):
    """Structured per-request validation failure.  ``reason`` is a
    stable machine-checkable code (``"overflow" | "bad_x_a" |
    "poisoned"``); ``detail`` is the human explanation."""

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


def resolve_future(fut: Optional[Future], result) -> bool:
    """Idempotent ``set_result`` — no-op on None or already-done."""
    if fut is not None and not fut.done():
        fut.set_result(result)
        return True
    return False


def fail_future(fut: Optional[Future], exc: BaseException) -> bool:
    """Idempotent ``set_exception`` — no-op on None or already-done."""
    if fut is not None and not fut.done():
        fut.set_exception(exc)
        return True
    return False


@dataclass
class Request:
    """One generation request against the split model.

    prompt          passive-party token ids, length >= 1 (consumed for
                    real during prefill — the slot's first ``len(prompt)``
                    steps feed these tokens into the cache)
    max_new_tokens  decode budget; the slot is evicted when reached
    temperature     0.0 = greedy argmax; > 0 = categorical sampling
    seed            per-request sampling key (counter-based jax.random)
    eos_id          optional stop token; eviction includes it in the output
    x_a             active party's private feature vector (d_active,);
                    zeros when omitted
    deadline_s      optional latency budget measured from submission:
                    queued requests past it are shed un-run
                    (finish_reason="expired", no tokens), running slots
                    are preempted at the first step past it (partial
                    tokens kept)
    """
    prompt: Sequence[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_id: Optional[int] = None
    x_a: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None

    # stamped by RequestQueue.submit
    rid: int = -1
    t_submit: float = 0.0
    future: Optional[Future] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError("deadline_s must be > 0 when given")

    @property
    def deadline(self) -> Optional[float]:
        """Absolute deadline on the submit clock, None when unbounded."""
        if self.deadline_s is None:
            return None
        return self.t_submit + self.deadline_s

    def expired(self, now: float) -> bool:
        d = self.deadline
        return d is not None and now > d


@dataclass
class Completion:
    """Resolved output of one request, with the latency breakdown the
    load benchmark aggregates (TTFT = t_first - t_submit)."""
    rid: int
    prompt_len: int
    tokens: List[int]
    t_submit: float
    t_admit: float
    t_first: float
    t_done: float
    finish_reason: str = "length"  # "length"|"eos"|"expired"|"aborted"|"error"
    error: Optional[str] = None    # detail when finish_reason == "error"

    @property
    def ok(self) -> bool:
        """True when the request ran to a normal stop condition."""
        return self.finish_reason in ("length", "eos")

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def decode_s(self) -> float:
        return self.t_done - self.t_first

    @property
    def per_token_s(self) -> float:
        """Mean inter-token latency after the first token."""
        n = len(self.tokens)
        return self.decode_s / (n - 1) if n > 1 else 0.0


def terminal_completion(req: Request, reason: str, now: float, *,
                        tokens: Optional[List[int]] = None,
                        error: Optional[str] = None) -> Completion:
    """A completion for a request that never (fully) ran: shed expired,
    aborted-at-exit, or failed validation."""
    return Completion(
        rid=req.rid, prompt_len=int(req.prompt.size),
        tokens=list(tokens or []), t_submit=req.t_submit, t_admit=now,
        t_first=0.0, t_done=now, finish_reason=reason, error=error)


class RequestQueue:
    """Thread-safe FIFO between producers (clients / the load generator)
    and the single scheduler thread.  Producers ``submit``; the scheduler
    ``try_get``s without blocking while slots are busy and ``wait``s when
    idle.  ``close`` ends the stream: the scheduler drains what is left
    and returns.

    capacity   None = unbounded (the PR-8 behaviour); an int bounds the
               backlog — admission control instead of silent latency
               collapse under overload
    policy     "reject": submit at capacity raises :class:`QueueFull`;
               "block": submit parks until space frees or the queue
               closes (:class:`QueueClosed`)
    validate   optional callable run against each submitted request
               BEFORE it is queued (raise :class:`RequestRejected`) —
               `ServeEngine.queue()` wires its shape checks in here so
               oversized/misshapen requests bounce at submit instead of
               poisoning the scheduler
    """

    POLICIES = ("reject", "block")

    def __init__(self, capacity: Optional[int] = None,
                 policy: str = "reject",
                 validate: Optional[Callable[[Request], None]] = None):
        if capacity is not None and capacity < 1:
            raise ValueError("capacity must be >= 1 (None = unbounded)")
        if policy not in self.POLICIES:
            raise ValueError(f"policy {policy!r} not in {self.POLICIES}")
        self.capacity = capacity
        self.policy = policy
        self._validate = validate
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._next_rid = 0

    # -- producer side --------------------------------------------------
    def _full(self) -> bool:
        return self.capacity is not None and len(self._q) >= self.capacity

    def submit(self, req: Request) -> Future:
        if self._validate is not None:
            self._validate(req)                 # raises RequestRejected
        with self._cv:
            if self.policy == "block":
                while self._full() and not self._closed:
                    self._cv.wait()
            if self._closed:
                raise QueueClosed("queue is closed")
            if self._full():
                raise QueueFull(self.capacity)
            req.rid = self._next_rid
            self._next_rid += 1
            req.t_submit = time.perf_counter()
            req.future = Future()
            self._q.append(req)
            self._cv.notify_all()
        return req.future

    def requeue(self, reqs: Sequence[Request]) -> None:
        """Put already-admitted requests back at the FRONT of the queue,
        keeping their rid/future/t_submit stamps.  Crash-recovery path:
        bypasses capacity, validation and the closed flag (the requests
        were admitted once; their clients still hold live futures)."""
        with self._cv:
            self._q.extendleft(reversed(list(reqs)))
            self._cv.notify_all()

    # -- scheduler side -------------------------------------------------
    def try_get(self) -> Optional[Request]:
        with self._cv:
            if not self._q:
                return None
            req = self._q.popleft()
            self._cv.notify_all()       # wake producers parked on "block"
            return req

    def wait(self, timeout: float) -> None:
        """Block until something is queued, the queue closes, or timeout."""
        with self._cv:
            if not self._q and not self._closed:
                self._cv.wait(timeout)

    def drain(self, close: bool = True) -> List[Request]:
        """Pop everything still queued (optionally closing the queue so
        late producers get :class:`QueueClosed` instead of a black
        hole).  The engine's abort/crash exit paths use this to resolve
        every outstanding future."""
        with self._cv:
            reqs = list(self._q)
            self._q.clear()
            if close:
                self._closed = True
            self._cv.notify_all()
        return reqs

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def empty(self) -> bool:
        with self._cv:
            return not self._q

    def __len__(self) -> int:
        with self._cv:
            return len(self._q)
