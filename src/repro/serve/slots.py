"""Slot-ring bookkeeping for continuous batching.

The compiled decode step has a fixed slot axis; this module owns the
host-side view of it: which slot holds which request, where each request
is in its prompt/decode lifecycle, and the free-slot ring that admission
draws from (the same ring discipline as the replay engine's embedding
rings: free slots recycle in eviction order, so a slot's cache region is
always either live for exactly one request or reset on admission).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Iterator, List, Optional

import numpy as np

from repro.serve.request import Completion, Request


class SlotState:
    """Lifecycle of one admitted request inside its slot.

    ``pos`` counts tokens fed so far.  While ``pos < len(prompt)`` the slot
    is prefilling (next feed = the real prompt token; sampled outputs are
    discarded).  The step that consumes ``prompt[-1]`` produces the first
    generated token — that transition stamps TTFT.
    """

    def __init__(self, req: Request, now: float):
        self.req = req
        self.pos = 0
        self.out: List[int] = []
        self.t_admit = now
        self.t_first = 0.0
        self.finish_reason = "length"
        self.deadline = req.deadline          # absolute, None = unbounded

    def expired(self, now: float) -> bool:
        """Past the request's deadline — the scheduler preempts the slot
        (partial tokens are kept, finish_reason becomes "expired")."""
        return self.deadline is not None and now > self.deadline

    def next_feed(self) -> int:
        if self.pos < self.req.prompt.size:
            return int(self.req.prompt[self.pos])
        return self.out[-1]

    def consume(self, sampled: int, now: float) -> bool:
        """Advance past the token just fed; record ``sampled`` if the fed
        token completed the prompt.  Returns True when finished."""
        self.pos += 1
        if self.pos < self.req.prompt.size:
            return False                        # still prefilling
        if not self.out:
            self.t_first = now
        self.out.append(sampled)
        if self.req.eos_id is not None and sampled == self.req.eos_id:
            self.finish_reason = "eos"
            return True
        return len(self.out) >= self.req.max_new_tokens

    def completion(self, now: float) -> Completion:
        return Completion(
            rid=self.req.rid, prompt_len=int(self.req.prompt.size),
            tokens=list(self.out), t_submit=self.req.t_submit,
            t_admit=self.t_admit, t_first=self.t_first, t_done=now,
            finish_reason=self.finish_reason)


class SlotRing:
    """Fixed-size slot pool: admission pops the free ring, eviction pushes
    back, active slots are iterated for feed/consume each step."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self._free: deque = deque(range(n_slots))
        self._state: List[Optional[SlotState]] = [None] * n_slots
        self.admitted = 0
        self.evicted = 0

    # -- admission / eviction ------------------------------------------
    def has_free(self) -> bool:
        return bool(self._free)

    def admit(self, req: Request, now: Optional[float] = None) -> int:
        slot = self._free.popleft()
        self._state[slot] = SlotState(
            req, time.perf_counter() if now is None else now)
        self.admitted += 1
        return slot

    def evict(self, slot: int, now: float) -> Completion:
        st = self._state[slot]
        assert st is not None, f"evicting empty slot {slot}"
        self._state[slot] = None
        self._free.append(slot)
        self.evicted += 1
        return st.completion(now)

    # -- per-step views -------------------------------------------------
    def any_active(self) -> bool:
        return len(self._free) < self.n_slots

    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def active_slots(self) -> Iterator[int]:
        return (i for i, s in enumerate(self._state) if s is not None)

    def state(self, slot: int) -> SlotState:
        st = self._state[slot]
        assert st is not None
        return st

    def feed_tokens(self) -> np.ndarray:
        """(n_slots,) int32 next-token feed; inactive slots feed 0 (their
        compute runs but is masked out of sampling and cache updates)."""
        toks = np.zeros((self.n_slots,), np.int32)
        for i, st in enumerate(self._state):
            if st is not None:
                toks[i] = st.next_feed()
        return toks

    def active_mask(self) -> np.ndarray:
        return np.asarray([s is not None for s in self._state], bool)
