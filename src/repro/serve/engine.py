"""Continuous-batching split-inference engine.

One compiled program per (arch, slot_count, cache_cap): the per-slot
decode step (``launch.steps.make_decode_step`` — bottom stack | cut
layer | f_a + top stack, cache-carrying) is vmapped over a fixed slot
axis and jitted once.  Every slot owns a private KV/recurrent-cache
region with its own position counter, so co-resident requests sit at
unrelated sequence offsets; sampling params (temperature, per-request
key) and the active mask are runtime operands, never recompiles.

The scheduler is a host loop: admit queued requests onto free slots
(resetting the slot's cache region), feed each active slot its next
token (real prompt tokens during prefill, the last sampled token during
decode), run the one compiled step, and evict slots on EOS/max-tokens
— resolving the request's future with a :class:`Completion`.

Bit-for-bit contract (pinned by tests/test_serve.py): a slot's output
stream depends only on its own request — not on which slot it landed
in, how full the batch is, or what traffic shares the batch — because
the vmapped program computes slots independently and inactive-slot
writes are masked out.

Robustness contract (pinned by tests/test_serve_robustness.py, see
docs/architecture.md §Robustness & overload):

* **no exit path hangs a client** — normal drain resolves futures at
  eviction; a ``max_steps`` abort resolves every in-flight and queued
  future with ``finish_reason="aborted"`` before raising; any exception
  escaping the step loop either fails every future
  (``Future.set_exception``, the default) or — under
  :func:`run_with_recovery` — re-queues the in-flight requests for
  replay and raises :class:`EngineCrashed`;
* **per-request validation never kills the batch** — an oversized
  request (``prompt_len + max_new_tokens > cache_cap``), a misshapen
  ``x_a``, or a fault-plan-poisoned rid fails only its own future
  (``finish_reason="error"``) while the rest of the batch keeps
  decoding;
* **deadlines are enforced on both sides of admission** — queued
  requests past ``deadline_s`` are shed un-run, running slots are
  preempted at the first step past it (partial tokens kept,
  ``finish_reason="expired"``);
* **crash recovery replays bit-for-bit** — engines of one
  (arch, slot_count, cache_cap) share ONE jitted program (the
  process-wide ``_PROGRAMS`` cache) and admission re-seeds the slot key
  from ``PRNGKey(req.seed)``, so a request replayed from its prompt on
  a rebuilt engine emits token-for-token the fault-free stream.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, get_config
from repro.launch.steps import make_decode_step, make_model
from repro.serve.faults import InjectedStepFailure, ServeFaultPlan
from repro.serve.request import (Completion, Request, RequestQueue,
                                 RequestRejected, fail_future,
                                 resolve_future, terminal_completion)
from repro.serve.slots import SlotRing

# process-wide program cache: (cfg, slots, cache_cap) -> _SlotPrograms.
# Engines sharing a key share ONE jitted step, so a request replayed on a
# different engine instance of the same shape is bitwise reproducible.
_PROGRAMS: Dict[Any, "_SlotPrograms"] = {}


class SchedulerAborted(RuntimeError):
    """The scheduler gave up (``max_steps`` exhausted with work still
    pending).  Every in-flight and queued future has already been
    resolved with ``finish_reason="aborted"`` when this reaches the
    caller."""


class EngineCrashed(RuntimeError):
    """The scheduler loop died mid-batch (injected crash or real bug).
    ``completed`` holds the completions finished before the crash (their
    futures are already resolved); the unfinished requests were either
    failed (``fail_futures=True``) or put back at the front of the queue
    for replay (``fail_futures=False`` — the `run_with_recovery`
    path)."""

    def __init__(self, msg: str, *, step: int,
                 completed: List[Completion]):
        super().__init__(msg)
        self.step = step
        self.completed = completed


class _SlotPrograms:
    def __init__(self, model, n_slots: int, cache_cap: int):
        decode = make_decode_step(model)

        def one_slot(params, tok, xa, temp, key, active, cache):
            batch = {"tokens_p": tok[None, None], "x_a": xa[None, None]}
            logits, new_cache = decode(params, batch, cache)
            logits = logits[0]                                    # (V,)
            greedy = jnp.argmax(logits).astype(jnp.int32)
            key, sub = jax.random.split(key)
            sampled = jax.random.categorical(
                sub, logits / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
            nxt = jnp.where(temp > 0.0, sampled, greedy)
            nxt = jnp.where(active, nxt, jnp.int32(0))
            # inactive slots keep their cache frozen (position included)
            new_cache = jax.tree.map(
                lambda n, o: jnp.where(active, n, o), new_cache, cache)
            return nxt, key, new_cache

        def admit(cache, keys, slot, new_key):
            cache = jax.tree.map(
                lambda a: a.at[slot].set(jnp.zeros(a.shape[1:], a.dtype)),
                cache)
            return cache, keys.at[slot].set(new_key)

        # donation keeps the slot caches in place off-CPU; XLA-CPU cannot
        # alias them and would warn, so gate like the replay engines do
        donate = (6,) if jax.default_backend() != "cpu" else ()
        self.step = jax.jit(
            jax.vmap(one_slot, in_axes=(None, 0, 0, 0, 0, 0, 0)),
            donate_argnums=donate)
        self.admit = jax.jit(admit)
        self.model = model
        self.n_slots = n_slots
        self.cache_cap = cache_cap

    @property
    def decode_compiles(self) -> int:
        return self.step._cache_size()


def slot_programs(cfg: ArchConfig, n_slots: int, cache_cap: int
                  ) -> _SlotPrograms:
    key = (cfg, n_slots, cache_cap)
    if key not in _PROGRAMS:
        _PROGRAMS[key] = _SlotPrograms(make_model(cfg), n_slots, cache_cap)
    return _PROGRAMS[key]


class ServeEngine:
    """Continuous-batching scheduler over one compiled slot program.

    Example::

        eng = ServeEngine("qwen2-0.5b", slots=8, cache_cap=64)
        outs = eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=16)])
        print(outs[0].tokens, outs[0].ttft_s)
    """

    def __init__(self, arch: Union[str, ArchConfig], *, slots: int = 4,
                 cache_cap: int = 64, params=None, seed: int = 0,
                 reduced: bool = True,
                 faults: Optional[ServeFaultPlan] = None,
                 max_step_retries: int = 3):
        if isinstance(arch, str):
            cfg = get_config(arch)
            cfg = cfg.reduced() if reduced else cfg
        else:
            cfg = arch
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        self.cfg = cfg
        self.n_slots = slots
        self.cache_cap = cache_cap
        self._seed = seed
        self._faults = faults
        self.max_step_retries = max_step_retries
        self._progs = slot_programs(cfg, slots, cache_cap)
        self.model = self._progs.model
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))

        self.ring = SlotRing(slots)
        self._cache = jax.vmap(
            lambda _: self.model.init_cache(1, cache_cap))(jnp.arange(slots))
        self._keys = jnp.stack([jax.random.PRNGKey(0)] * slots)
        self._xa = np.zeros((slots, cfg.d_active), np.float32)
        self._temps = np.zeros((slots,), np.float32)

        self._steps = 0
        self._slot_steps = 0
        self.last_run_stats: Dict[str, Any] = {}

    def respawn(self) -> "ServeEngine":
        """A fresh engine of the same shape/params/fault plan — the
        crash-recovery rebuild.  Same ``_PROGRAMS`` key, so the replayed
        requests go through the very same compiled step."""
        return ServeEngine(self.cfg, slots=self.n_slots,
                           cache_cap=self.cache_cap, params=self.params,
                           seed=self._seed, faults=self._faults,
                           max_step_retries=self.max_step_retries)

    # -- admission ------------------------------------------------------
    def _reject_reason(self, req: Request, *, at_admit: bool = False
                       ) -> Optional[RequestRejected]:
        """Why this request cannot run on this engine, or None.  Poison
        faults only manifest at admit (the request *looks* valid to
        submit-side validation, the point of that fault mode)."""
        need = int(req.prompt.size) + req.max_new_tokens
        if need > self.cache_cap:
            return RequestRejected(
                "overflow",
                f"prompt_len + max_new_tokens = {need} exceeds the "
                f"slot cache capacity {self.cache_cap} (the KV ring "
                "would wrap and emit garbage)")
        if req.x_a is not None:
            xa = np.asarray(req.x_a, np.float32).reshape(-1)
            if xa.size != self.cfg.d_active:
                return RequestRejected(
                    "bad_x_a",
                    f"x_a has {xa.size} features, engine expects "
                    f"d_active={self.cfg.d_active}")
        if (at_admit and self._faults is not None
                and self._faults.poisoned(req.rid)):
            return RequestRejected(
                "poisoned", f"rid {req.rid} poisoned by the fault plan")
        return None

    def validate(self, req: Request) -> None:
        """Submit-side validation: raise :class:`RequestRejected` for a
        request this engine can never serve."""
        err = self._reject_reason(req)
        if err is not None:
            raise err

    def queue(self, *, capacity: Optional[int] = None,
              policy: str = "reject") -> RequestQueue:
        """A request queue wired to this engine: submit-side shape
        validation plus optional bounded-capacity admission control."""
        return RequestQueue(capacity=capacity, policy=policy,
                            validate=self.validate)

    def _admit(self, req: Request) -> int:
        slot = self.ring.admit(req)
        self._cache, self._keys = self._progs.admit(
            self._cache, self._keys, jnp.int32(slot),
            jax.random.PRNGKey(req.seed))
        self._temps[slot] = req.temperature
        self._xa[slot] = (0.0 if req.x_a is None
                          else np.asarray(req.x_a, np.float32).reshape(-1))
        return slot

    def _reset_slots(self) -> None:
        """Drop all slot state after a crash — the rebuilt/reused engine
        starts from an empty ring and zeroed host-side operands."""
        self.ring = SlotRing(self.n_slots)
        self._cache = jax.vmap(
            lambda _: self.model.init_cache(1, self.cache_cap))(
                jnp.arange(self.n_slots))
        self._keys = jnp.stack([jax.random.PRNGKey(0)] * self.n_slots)
        self._xa = np.zeros((self.n_slots, self.cfg.d_active), np.float32)
        self._temps = np.zeros((self.n_slots,), np.float32)

    # -- scheduler loop -------------------------------------------------
    def run(self, queue: RequestQueue, *, max_steps: Optional[int] = None,
            idle_wait: float = 0.002, fail_futures: bool = True
            ) -> List[Completion]:
        """Drive the slot batch until ``queue`` is closed and drained.
        Returns the completions in eviction order (each request's future
        is resolved the moment its slot is evicted; shed/errored
        requests get terminal completions in the same list).

        fail_futures   what an escaping exception does to unfinished
                       requests: True (default) fails every future so no
                       client ever hangs; False re-queues the in-flight
                       requests at the front of ``queue`` and leaves
                       futures pending — ONLY for a caller that commits
                       to retrying (`run_with_recovery`) or failing them
                       itself."""
        done: List[Completion] = []
        counters = {"shed_expired": 0, "preempted": 0, "rejected": 0,
                    "step_retries": 0, "injected_stall_s": 0.0}
        steps0, slot_steps0 = self._steps, self._slot_steps
        t0 = time.perf_counter()
        try:
            while True:
                # admit: validate / shed / place queued requests
                while self.ring.has_free():
                    req = queue.try_get()
                    if req is None:
                        break
                    now = time.perf_counter()
                    err = self._reject_reason(req, at_admit=True)
                    if err is not None:
                        comp = terminal_completion(
                            req, "error", now, error=str(err))
                        counters["rejected"] += 1
                        done.append(comp)
                        resolve_future(req.future, comp)
                        continue
                    if req.expired(now):
                        comp = terminal_completion(req, "expired", now)
                        counters["shed_expired"] += 1
                        done.append(comp)
                        resolve_future(req.future, comp)
                        continue
                    self._admit(req)
                if not self.ring.any_active():
                    if queue.closed and queue.empty():
                        break
                    queue.wait(idle_wait)
                    continue
                if (max_steps is not None
                        and self._steps - steps0 >= max_steps):
                    raise SchedulerAborted(
                        f"scheduler exceeded max_steps={max_steps} with "
                        f"{self.ring.n_active()} slots still active")

                # fault hooks: stall/drift, transient step failure
                # (retried — nothing was mutated yet), fatal crash
                try:
                    if self._faults is not None:
                        dt = self._faults.stall_s_at(self._steps)
                        if dt > 0:
                            counters["injected_stall_s"] += dt
                            time.sleep(dt)
                        if self._faults.take_step_failure(self._steps):
                            raise InjectedStepFailure(self._steps)
                        self._faults.maybe_crash(self._steps)
                except InjectedStepFailure:
                    counters["step_retries"] += 1
                    if counters["step_retries"] > self.max_step_retries:
                        raise RuntimeError(
                            "step retry budget exhausted "
                            f"({self.max_step_retries})")
                    continue                   # retry: inputs untouched

                toks = self.ring.feed_tokens()
                active = self.ring.active_mask()
                nxt, self._keys, self._cache = self._progs.step(
                    self.params, jnp.asarray(toks), jnp.asarray(self._xa),
                    jnp.asarray(self._temps), self._keys,
                    jnp.asarray(active), self._cache)
                nxt_host = np.asarray(nxt)      # sync point of the step
                now = time.perf_counter()
                self._steps += 1
                self._slot_steps += self.ring.n_active()

                for slot in list(self.ring.active_slots()):
                    st = self.ring.state(slot)
                    if st.consume(int(nxt_host[slot]), now):
                        comp = self.ring.evict(slot, now)
                        done.append(comp)
                        resolve_future(st.req.future, comp)
                    elif st.expired(now):
                        # deadline preemption: partial tokens kept
                        st.finish_reason = "expired"
                        comp = self.ring.evict(slot, now)
                        counters["preempted"] += 1
                        done.append(comp)
                        resolve_future(st.req.future, comp)
        except SchedulerAborted:
            # resolve EVERYTHING before surfacing: in-flight slots keep
            # their partial tokens, queued requests abort un-run
            now = time.perf_counter()
            for slot in list(self.ring.active_slots()):
                st = self.ring.state(slot)
                st.finish_reason = "aborted"
                comp = self.ring.evict(slot, now)
                done.append(comp)
                resolve_future(st.req.future, comp)
            for req in queue.drain(close=True):
                comp = terminal_completion(req, "aborted", now)
                done.append(comp)
                resolve_future(req.future, comp)
            self._finish_stats(done, counters, steps0, slot_steps0, t0)
            raise
        except BaseException as cause:
            inflight = sorted(
                (self.ring.state(s).req for s in self.ring.active_slots()),
                key=lambda r: r.rid)
            self._reset_slots()
            crash = EngineCrashed(
                f"serve engine crashed at step {self._steps}: {cause!r}",
                step=self._steps, completed=list(done))
            # KeyboardInterrupt/SystemExit are process kills, not
            # engine faults: fail the futures (no hangs) but propagate
            # the original so recovery never "retries" a Ctrl-C
            if fail_futures or not isinstance(cause, Exception):
                for req in inflight:
                    fail_future(req.future, crash)
                for req in queue.drain(close=True):
                    fail_future(req.future, crash)
            else:
                queue.requeue(inflight)
            self._finish_stats(done, counters, steps0, slot_steps0, t0)
            if not isinstance(cause, Exception):
                raise
            raise crash from cause
        self._finish_stats(done, counters, steps0, slot_steps0, t0)
        return done

    def _finish_stats(self, done, counters, steps0, slot_steps0,
                      t0) -> None:
        steps = self._steps - steps0
        slot_steps = self._slot_steps - slot_steps0
        self.last_run_stats = {
            "steps": steps, "slot_steps": slot_steps,
            "occupancy": slot_steps / max(steps * self.n_slots, 1),
            "completed": len(done),
            "completed_ok": sum(c.ok for c in done),
            "wall_s": time.perf_counter() - t0,
            "decode_compiles": self._progs.decode_compiles,
            **counters,
        }

    def serve(self, requests: Sequence[Request], **kw) -> List[Completion]:
        """Closed-loop convenience: submit everything, drain, return
        completions in submission order (invalid requests come back as
        ``finish_reason="error"`` completions, not exceptions)."""
        q = RequestQueue()
        for r in requests:
            q.submit(r)
        q.close()
        return sorted(self.run(q, **kw), key=lambda c: c.rid)

    # -- observability --------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "steps": self._steps, "slot_steps": self._slot_steps,
            "occupancy": self._slot_steps / max(
                self._steps * self.n_slots, 1),
            "admitted": self.ring.admitted, "evicted": self.ring.evicted,
            "decode_compiles": self._progs.decode_compiles,
        }


# ---------------------------------------------------------------------------
class RecoveryGaveUp(RuntimeError):
    """`run_with_recovery` exhausted ``max_restarts``.  Every still-
    unfinished future has been failed with the final `EngineCrashed`
    before this raises — clients never hang."""


class RecoveryResult:
    """Outcome of `run_with_recovery`: the merged completions (crash
    survivors + replays), how many times the engine was rebuilt, the
    per-recovery latency, and the engine that finished the run."""

    def __init__(self, completions: List[Completion], restarts: int,
                 recovery_s: List[float], engine: ServeEngine):
        self.completions = completions
        self.restarts = restarts
        self.recovery_s = recovery_s
        self.engine = engine


def run_with_recovery(engine: ServeEngine, queue: RequestQueue, *,
                      max_restarts: int = 3, backoff_s: float = 0.01,
                      rebuild: Optional[Callable[[ServeEngine],
                                                 ServeEngine]] = None,
                      **run_kw) -> RecoveryResult:
    """Drive ``engine.run(queue)`` under a crash watchdog: whenever the
    scheduler dies (`EngineCrashed`), rebuild the engine (default:
    ``engine.respawn()`` — same shape, same params, same compiled
    program) after exponential backoff and keep serving the SAME queue.
    The crashed run has already put its in-flight requests back at the
    front of the queue, so they replay from their prompts —
    token-for-token identical to a fault-free run, because admission
    re-seeds the slot from ``PRNGKey(req.seed)`` and the slot program is
    shared process-wide (`tests/test_serve_robustness.py` pins this).

    Completions finished before each crash are kept (their futures
    resolved at eviction); after ``max_restarts`` recoveries every
    still-pending future is failed and `RecoveryGaveUp` raises."""
    rebuild = rebuild or (lambda old: old.respawn())
    done: List[Completion] = []
    recovery_s: List[float] = []
    restarts = 0
    eng = engine
    while True:
        try:
            done += eng.run(queue, fail_futures=False, **run_kw)
            return RecoveryResult(sorted(done, key=lambda c: c.rid),
                                  restarts, recovery_s, eng)
        except EngineCrashed as crash:
            done += crash.completed
            restarts += 1
            if restarts > max_restarts:
                gave_up = RecoveryGaveUp(
                    f"engine crashed {restarts} times "
                    f"(max_restarts={max_restarts}): {crash}")
                gave_up.__cause__ = crash
                for req in queue.drain(close=True):
                    fail_future(req.future, gave_up)
                raise gave_up
            t_rec = time.perf_counter()
            if backoff_s > 0:
                time.sleep(backoff_s * (2 ** (restarts - 1)))
            eng = rebuild(eng)
            recovery_s.append(time.perf_counter() - t_rec)


# ---------------------------------------------------------------------------
def reference_decode(cfg: ArchConfig, params, req: Request, *,
                     cache_cap: int = 64) -> List[int]:
    """Plain single-request greedy/sampled decode (batch 1, no slot axis)
    — the token-level oracle the slot-batched path is tested against.
    XLA specializes B=1 differently, so parity with the slot program is
    token-exact rather than bitwise (the bitwise contract lives between
    occupancies of ONE compiled slot program)."""
    model = make_model(cfg)
    decode = jax.jit(make_decode_step(model))
    cache = model.init_cache(1, cache_cap)
    xa = jnp.asarray(
        np.zeros((1, 1, cfg.d_active), np.float32) if req.x_a is None
        else np.asarray(req.x_a, np.float32).reshape(1, 1, -1))
    key = jax.random.PRNGKey(req.seed)
    prompt = np.asarray(req.prompt, np.int32)
    plen = prompt.size
    pos = 0
    out: List[int] = []
    feed = int(prompt[0])
    # mirror the slot program's step structure exactly: one key split per
    # step (prefill steps included), sample kept once the prompt is done
    while True:
        logits, cache = decode(
            params,
            {"tokens_p": jnp.asarray([[feed]], jnp.int32), "x_a": xa},
            cache)
        key, sub = jax.random.split(key)
        if req.temperature > 0:
            tok = int(jax.random.categorical(
                sub, logits[0] / max(req.temperature, 1e-6)))
        else:
            tok = int(jnp.argmax(logits[0]))
        pos += 1
        if pos >= plen:
            out.append(tok)
            if req.eos_id is not None and tok == req.eos_id:
                break
            if len(out) >= req.max_new_tokens:
                break
        feed = int(prompt[pos]) if pos < plen else out[-1]
    return out
