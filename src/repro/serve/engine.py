"""Continuous-batching split-inference engine.

One compiled program per (arch, slot_count, cache_cap): the per-slot
decode step (``launch.steps.make_decode_step`` — bottom stack | cut
layer | f_a + top stack, cache-carrying) is vmapped over a fixed slot
axis and jitted once.  Every slot owns a private KV/recurrent-cache
region with its own position counter, so co-resident requests sit at
unrelated sequence offsets; sampling params (temperature, per-request
key) and the active mask are runtime operands, never recompiles.

The scheduler is a host loop: admit queued requests onto free slots
(resetting the slot's cache region), feed each active slot its next
token (real prompt tokens during prefill, the last sampled token during
decode), run the one compiled step, and evict slots on EOS/max-tokens
— resolving the request's future with a :class:`Completion`.

Bit-for-bit contract (pinned by tests/test_serve.py): a slot's output
stream depends only on its own request — not on which slot it landed
in, how full the batch is, or what traffic shares the batch — because
the vmapped program computes slots independently and inactive-slot
writes are masked out.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, get_config
from repro.launch.steps import make_decode_step, make_model
from repro.serve.request import Completion, Request, RequestQueue
from repro.serve.slots import SlotRing

# process-wide program cache: (cfg, slots, cache_cap) -> _SlotPrograms.
# Engines sharing a key share ONE jitted step, so a request replayed on a
# different engine instance of the same shape is bitwise reproducible.
_PROGRAMS: Dict[Any, "_SlotPrograms"] = {}


class _SlotPrograms:
    def __init__(self, model, n_slots: int, cache_cap: int):
        decode = make_decode_step(model)

        def one_slot(params, tok, xa, temp, key, active, cache):
            batch = {"tokens_p": tok[None, None], "x_a": xa[None, None]}
            logits, new_cache = decode(params, batch, cache)
            logits = logits[0]                                    # (V,)
            greedy = jnp.argmax(logits).astype(jnp.int32)
            key, sub = jax.random.split(key)
            sampled = jax.random.categorical(
                sub, logits / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
            nxt = jnp.where(temp > 0.0, sampled, greedy)
            nxt = jnp.where(active, nxt, jnp.int32(0))
            # inactive slots keep their cache frozen (position included)
            new_cache = jax.tree.map(
                lambda n, o: jnp.where(active, n, o), new_cache, cache)
            return nxt, key, new_cache

        def admit(cache, keys, slot, new_key):
            cache = jax.tree.map(
                lambda a: a.at[slot].set(jnp.zeros(a.shape[1:], a.dtype)),
                cache)
            return cache, keys.at[slot].set(new_key)

        # donation keeps the slot caches in place off-CPU; XLA-CPU cannot
        # alias them and would warn, so gate like the replay engines do
        donate = (6,) if jax.default_backend() != "cpu" else ()
        self.step = jax.jit(
            jax.vmap(one_slot, in_axes=(None, 0, 0, 0, 0, 0, 0)),
            donate_argnums=donate)
        self.admit = jax.jit(admit)
        self.model = model
        self.n_slots = n_slots
        self.cache_cap = cache_cap

    @property
    def decode_compiles(self) -> int:
        return self.step._cache_size()


def slot_programs(cfg: ArchConfig, n_slots: int, cache_cap: int
                  ) -> _SlotPrograms:
    key = (cfg, n_slots, cache_cap)
    if key not in _PROGRAMS:
        _PROGRAMS[key] = _SlotPrograms(make_model(cfg), n_slots, cache_cap)
    return _PROGRAMS[key]


class ServeEngine:
    """Continuous-batching scheduler over one compiled slot program.

    Example::

        eng = ServeEngine("qwen2-0.5b", slots=8, cache_cap=64)
        outs = eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=16)])
        print(outs[0].tokens, outs[0].ttft_s)
    """

    def __init__(self, arch: Union[str, ArchConfig], *, slots: int = 4,
                 cache_cap: int = 64, params=None, seed: int = 0,
                 reduced: bool = True):
        if isinstance(arch, str):
            cfg = get_config(arch)
            cfg = cfg.reduced() if reduced else cfg
        else:
            cfg = arch
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        self.cfg = cfg
        self.n_slots = slots
        self.cache_cap = cache_cap
        self._progs = slot_programs(cfg, slots, cache_cap)
        self.model = self._progs.model
        self.params = (params if params is not None
                       else self.model.init(jax.random.PRNGKey(seed)))

        self.ring = SlotRing(slots)
        self._cache = jax.vmap(
            lambda _: self.model.init_cache(1, cache_cap))(jnp.arange(slots))
        self._keys = jnp.stack([jax.random.PRNGKey(0)] * slots)
        self._xa = np.zeros((slots, cfg.d_active), np.float32)
        self._temps = np.zeros((slots,), np.float32)

        self._steps = 0
        self._slot_steps = 0
        self.last_run_stats: Dict[str, Any] = {}

    # -- admission ------------------------------------------------------
    def _admit(self, req: Request) -> int:
        slot = self.ring.admit(req)
        self._cache, self._keys = self._progs.admit(
            self._cache, self._keys, jnp.int32(slot),
            jax.random.PRNGKey(req.seed))
        self._temps[slot] = req.temperature
        self._xa[slot] = (0.0 if req.x_a is None
                          else np.asarray(req.x_a, np.float32))
        return slot

    # -- scheduler loop -------------------------------------------------
    def run(self, queue: RequestQueue, *, max_steps: Optional[int] = None,
            idle_wait: float = 0.002) -> List[Completion]:
        """Drive the slot batch until ``queue`` is closed and drained.
        Returns the completions in eviction order (each request's future
        is resolved the moment its slot is evicted)."""
        done: List[Completion] = []
        steps0, slot_steps0 = self._steps, self._slot_steps
        t0 = time.perf_counter()
        while True:
            while self.ring.has_free():
                req = queue.try_get()
                if req is None:
                    break
                self._admit(req)
            if not self.ring.any_active():
                if queue.closed and queue.empty():
                    break
                queue.wait(idle_wait)
                continue

            toks = self.ring.feed_tokens()
            active = self.ring.active_mask()
            nxt, self._keys, self._cache = self._progs.step(
                self.params, jnp.asarray(toks), jnp.asarray(self._xa),
                jnp.asarray(self._temps), self._keys, jnp.asarray(active),
                self._cache)
            nxt_host = np.asarray(nxt)          # sync point of the step
            now = time.perf_counter()
            self._steps += 1
            self._slot_steps += self.ring.n_active()

            for slot in list(self.ring.active_slots()):
                st = self.ring.state(slot)
                if st.consume(int(nxt_host[slot]), now):
                    comp = self.ring.evict(slot, now)
                    done.append(comp)
                    if st.req.future is not None:
                        st.req.future.set_result(comp)
            if max_steps is not None and self._steps - steps0 >= max_steps:
                raise RuntimeError(
                    f"scheduler exceeded max_steps={max_steps} with "
                    f"{self.ring.n_active()} slots still active")
        steps = self._steps - steps0
        slot_steps = self._slot_steps - slot_steps0
        self.last_run_stats = {
            "steps": steps, "slot_steps": slot_steps,
            "occupancy": slot_steps / max(steps * self.n_slots, 1),
            "completed": len(done), "wall_s": time.perf_counter() - t0,
            "decode_compiles": self._progs.decode_compiles,
        }
        return done

    def serve(self, requests: Sequence[Request], **kw) -> List[Completion]:
        """Closed-loop convenience: submit everything, drain, return
        completions in submission order."""
        q = RequestQueue()
        for r in requests:
            q.submit(r)
        q.close()
        return sorted(self.run(q, **kw), key=lambda c: c.rid)

    # -- observability --------------------------------------------------
    @property
    def stats(self) -> Dict[str, Any]:
        return {
            "steps": self._steps, "slot_steps": self._slot_steps,
            "occupancy": self._slot_steps / max(
                self._steps * self.n_slots, 1),
            "admitted": self.ring.admitted, "evicted": self.ring.evicted,
            "decode_compiles": self._progs.decode_compiles,
        }


# ---------------------------------------------------------------------------
def reference_decode(cfg: ArchConfig, params, req: Request, *,
                     cache_cap: int = 64) -> List[int]:
    """Plain single-request greedy/sampled decode (batch 1, no slot axis)
    — the token-level oracle the slot-batched path is tested against.
    XLA specializes B=1 differently, so parity with the slot program is
    token-exact rather than bitwise (the bitwise contract lives between
    occupancies of ONE compiled slot program)."""
    model = make_model(cfg)
    decode = jax.jit(make_decode_step(model))
    cache = model.init_cache(1, cache_cap)
    xa = jnp.asarray(
        np.zeros((1, 1, cfg.d_active), np.float32) if req.x_a is None
        else np.asarray(req.x_a, np.float32).reshape(1, 1, -1))
    key = jax.random.PRNGKey(req.seed)
    prompt = np.asarray(req.prompt, np.int32)
    plen = prompt.size
    pos = 0
    out: List[int] = []
    feed = int(prompt[0])
    # mirror the slot program's step structure exactly: one key split per
    # step (prefill steps included), sample kept once the prompt is done
    while True:
        logits, cache = decode(
            params,
            {"tokens_p": jnp.asarray([[feed]], jnp.int32), "x_a": xa},
            cache)
        key, sub = jax.random.split(key)
        if req.temperature > 0:
            tok = int(jax.random.categorical(
                sub, logits[0] / max(req.temperature, 1e-6)))
        else:
            tok = int(jnp.argmax(logits[0]))
        pos += 1
        if pos >= plen:
            out.append(tok)
            if req.eos_id is not None and tok == req.eos_id:
                break
            if len(out) >= req.max_new_tokens:
                break
        feed = int(prompt[pos]) if pos < plen else out[-1]
    return out
