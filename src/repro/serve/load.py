"""Open-loop load generation: Poisson arrivals against a ServeEngine.

The generator thread submits requests with exponential inter-arrival
gaps (offered rate = ``qps``) while the scheduler drains the queue in
the caller's thread — arrivals never block on any single request, which
is the serving half of the Pub/Sub decoupling argument.

Robustness hooks: pass an engine-wired bounded ``queue``
(`ServeEngine.queue(capacity=..., policy="reject")`) and the generator
absorbs admission-control rejections (`QueueFull` / `RequestRejected`)
instead of dying — rejected offers are counted in ``events``;
``recover=True`` drives the scheduler through
`engine.run_with_recovery` so an engine crash mid-load is rebuilt and
the in-flight requests replay from their prompts.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeEngine, run_with_recovery
from repro.serve.request import (Completion, QueueClosed, QueueFull,
                                 Request, RequestQueue, RequestRejected)


def synthetic_requests(n: int, vocab_size: int, *, seed: int = 0,
                       prompt_lens=(4, 12), max_new_tokens: int = 16,
                       temperature: float = 0.0,
                       deadline_s: Optional[float] = None
                       ) -> List[Request]:
    """Deterministic request mix: uniform prompt lengths, seeded prompts."""
    rng = np.random.default_rng(seed)
    lo, hi = prompt_lens
    out = []
    for i in range(n):
        plen = int(rng.integers(lo, hi + 1))
        out.append(Request(
            prompt=rng.integers(0, vocab_size, size=(plen,)),
            max_new_tokens=max_new_tokens, temperature=temperature,
            seed=seed + i, deadline_s=deadline_s))
    return out


def open_loop(engine: ServeEngine, requests: Sequence[Request], qps: float,
              *, seed: int = 0, max_steps: Optional[int] = None,
              queue: Optional[RequestQueue] = None, recover: bool = False,
              max_restarts: int = 3, events: Optional[Dict] = None
              ) -> List[Completion]:
    """Submit ``requests`` at Poisson rate ``qps`` and drain the engine.
    Returns completions in submission order.  ``events`` (if given) is
    filled with offered/rejected counts and — under ``recover=True`` —
    restart count and per-recovery latency."""
    if qps <= 0:
        raise ValueError("qps must be positive")
    queue = queue if queue is not None else RequestQueue()
    gaps = np.random.default_rng(seed).exponential(1.0 / qps,
                                                   size=len(requests))
    counts = {"offered": 0, "rejected": 0}

    def generator():
        for req, gap in zip(requests, gaps):
            time.sleep(gap)
            counts["offered"] += 1
            try:
                queue.submit(req)
            except (QueueFull, RequestRejected):
                counts["rejected"] += 1       # admission control said no
            except QueueClosed:
                break                         # engine died / run aborted
        queue.close()

    t = threading.Thread(target=generator, daemon=True)
    t.start()
    if recover:
        res = run_with_recovery(engine, queue, max_steps=max_steps,
                                max_restarts=max_restarts)
        done = res.completions
        if events is not None:
            events["restarts"] = res.restarts
            events["recovery_s"] = list(res.recovery_s)
    else:
        done = engine.run(queue, max_steps=max_steps)
    t.join()
    if events is not None:
        events.update(counts)
    return sorted(done, key=lambda c: c.rid)
