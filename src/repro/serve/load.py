"""Open-loop load generation: Poisson arrivals against a ServeEngine.

The generator thread submits requests with exponential inter-arrival
gaps (offered rate = ``qps``) while the scheduler drains the queue in
the caller's thread — arrivals never block on any single request, which
is the serving half of the Pub/Sub decoupling argument.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.engine import ServeEngine
from repro.serve.request import Completion, Request, RequestQueue


def synthetic_requests(n: int, vocab_size: int, *, seed: int = 0,
                       prompt_lens=(4, 12), max_new_tokens: int = 16,
                       temperature: float = 0.0) -> List[Request]:
    """Deterministic request mix: uniform prompt lengths, seeded prompts."""
    rng = np.random.default_rng(seed)
    lo, hi = prompt_lens
    out = []
    for i in range(n):
        plen = int(rng.integers(lo, hi + 1))
        out.append(Request(
            prompt=rng.integers(0, vocab_size, size=(plen,)),
            max_new_tokens=max_new_tokens, temperature=temperature,
            seed=seed + i))
    return out


def open_loop(engine: ServeEngine, requests: Sequence[Request], qps: float,
              *, seed: int = 0, max_steps: Optional[int] = None
              ) -> List[Completion]:
    """Submit ``requests`` at Poisson rate ``qps`` and drain the engine.
    Returns completions in submission order."""
    if qps <= 0:
        raise ValueError("qps must be positive")
    queue = RequestQueue()
    gaps = np.random.default_rng(seed).exponential(1.0 / qps,
                                                   size=len(requests))

    def generator():
        for req, gap in zip(requests, gaps):
            time.sleep(gap)
            queue.submit(req)
        queue.close()

    t = threading.Thread(target=generator, daemon=True)
    t.start()
    done = engine.run(queue, max_steps=max_steps)
    t.join()
    return sorted(done, key=lambda c: c.rid)
