"""Continuous-batching split-inference serving.

Request queue → slot-ring KV/recurrent caches → one jitted joint decode
step per (arch, slot_count, cache_cap).  See docs/architecture.md
§Split-inference serving.
"""
from repro.serve.engine import ServeEngine, reference_decode, slot_programs
from repro.serve.load import open_loop, synthetic_requests
from repro.serve.request import Completion, Request, RequestQueue
from repro.serve.slots import SlotRing, SlotState

__all__ = [
    "ServeEngine", "Request", "RequestQueue", "Completion", "SlotRing",
    "SlotState", "open_loop", "synthetic_requests", "reference_decode",
    "slot_programs",
]
