"""Continuous-batching split-inference serving.

Request queue → slot-ring KV/recurrent caches → one jitted joint decode
step per (arch, slot_count, cache_cap), with admission control,
deadlines, deterministic fault injection and crash recovery.  See
docs/architecture.md §Split-inference serving and §Robustness &
overload.
"""
from repro.serve.engine import (EngineCrashed, RecoveryGaveUp,
                                RecoveryResult, SchedulerAborted,
                                ServeEngine, reference_decode,
                                run_with_recovery, slot_programs)
from repro.serve.faults import (InjectedCrash, InjectedStepFailure,
                                ServeFaultPlan, StepStall, StragglerDrift)
from repro.serve.load import open_loop, synthetic_requests
from repro.serve.request import (FINISH_REASONS, Completion, QueueClosed,
                                 QueueFull, Request, RequestQueue,
                                 RequestRejected, fail_future,
                                 resolve_future, terminal_completion)
from repro.serve.slots import SlotRing, SlotState

__all__ = [
    "ServeEngine", "Request", "RequestQueue", "Completion", "SlotRing",
    "SlotState", "open_loop", "synthetic_requests", "reference_decode",
    "slot_programs",
    # robustness layer
    "FINISH_REASONS", "QueueClosed", "QueueFull", "RequestRejected",
    "SchedulerAborted", "EngineCrashed", "RecoveryGaveUp",
    "RecoveryResult", "run_with_recovery", "ServeFaultPlan", "StepStall",
    "StragglerDrift", "InjectedCrash", "InjectedStepFailure",
    "resolve_future", "fail_future", "terminal_completion",
]
