"""qwen2-vl-2b [vlm] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE [arXiv:2409.12191]; ViT frontend stubbed"""
from repro.configs.archs import QWEN2_VL_2B as CONFIG

REDUCED = CONFIG.reduced()
