"""qwen3-moe-30b-a3b [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768 vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.archs import QWEN3_MOE as CONFIG

REDUCED = CONFIG.reduced()
