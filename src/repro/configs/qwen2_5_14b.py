"""qwen2.5-14b [dense] 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5 family]"""
from repro.configs.archs import QWEN25_14B as CONFIG

REDUCED = CONFIG.reduced()
