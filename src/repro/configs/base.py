"""Architecture + input-shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`.  The
model stack (``repro.models``) consumes only this dataclass, so adding an
architecture means adding one file in ``repro/configs/``.

A config describes the *joint* model of the two-party split-learning setup
(paper Fig. 1): the passive party holds the bottom stack (layers
``[0, cut_layer)``), the active party holds its private feature encoder
``f_a`` plus the top stack (layers ``[cut_layer, n_layers)``) and the head.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------
# A layer is (mixer, ffn):
#   mixer ∈ {"attn", "mla", "local_attn", "rglru", "rwkv"}
#   ffn   ∈ {"dense", "moe", "rwkv_cm", "none"}
LayerSpec = Tuple[str, str]
# A stage is (repeat, pattern): scan `repeat` times over the layer pattern.
Stage = Tuple[int, Tuple[LayerSpec, ...]]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    source: str                      # citation for the architecture
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                # "silu" (SwiGLU) | "gelu" (GeGLU)
    causal: bool = True              # False => encoder-only (hubert)
    tie_embeddings: bool = False

    # Stage layout.  If empty, defaults to n_layers x ("attn","dense").
    stages: Tuple[Stage, ...] = ()
    sliding_window: Optional[int] = None   # window for "local_attn" layers

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense-FFN layers (deepseek)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 32

    # --- RG-LRU (RecurrentGemma) ---
    lru_width: Optional[int] = None
    conv_width: int = 4

    # --- modality frontend stub ([audio] / [vlm]) ---
    frontend: Optional[str] = None   # None | "audio_frames" | "vision_patches"
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # --- split-learning placement ---
    cut_layer: Optional[int] = None  # default n_layers // 2
    d_active: int = 64               # active party's raw feature dim (f_a input)

    # --- numerics ---
    dtype: str = "float32"           # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = False              # checkpoint each scanned layer group
    remat_policy: str = "full"       # "full" | "dots" (save matmul outs)
    ce_chunk: int = 0                # >0: chunked cross-entropy (§Perf)
    moe_dispatch_i8: bool = False    # int8 one-hot in MoE dispatch (§Perf)
    act_spec: Tuple[str, ...] = ()   # batch axes to pin activations to
                                     # (kills XLA resharding churn; §Perf)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def resolved_cut(self) -> int:
        return self.cut_layer if self.cut_layer is not None else self.n_layers // 2

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def resolved_stages(self) -> Tuple[Stage, ...]:
        if self.stages:
            return self.stages
        return ((self.n_layers, (("attn", "dense"),)),)

    @property
    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        out = []
        for repeat, pattern in self.resolved_stages:
            out.extend(list(pattern) * repeat)
        return tuple(out)

    @property
    def is_subquadratic(self) -> bool:
        """True iff no layer needs an unbounded full-attention KV cache."""
        for mixer, _ in self.layer_specs:
            if mixer in ("attn", "mla") and self.sliding_window is None:
                return False
        return True

    @property
    def supports_decode(self) -> bool:
        return self.causal

    def validate(self) -> None:
        assert len(self.layer_specs) == self.n_layers, (
            f"{self.name}: stages sum to {len(self.layer_specs)} != n_layers "
            f"{self.n_layers}")
        cut = self.resolved_cut
        assert 0 < cut < self.n_layers

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = max(8, d // heads)
        pattern = self.resolved_stages[-1][1][:1]  # representative layer kind
        # keep the family's signature layer; 2 layers of it
        stages = ((2, pattern),)
        if self.family == "hybrid":
            stages = ((1, (("rglru", "dense"), ("attn", "dense"))),)
        kw = dict(
            n_layers=sum(r * len(p) for r, p in stages),
            d_model=d, n_heads=heads, n_kv_heads=kv, head_dim=hd,
            d_ff=min(self.d_ff, 4 * d), vocab_size=min(self.vocab_size, 512),
            stages=stages, cut_layer=1, lru_width=d if self.lru_width else None,
            dtype="float32", param_dtype="float32", remat=False,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      moe_d_ff=min(self.moe_d_ff, d), n_dense_layers=0)
        if self.kv_lora_rank:
            kw.update(kv_lora_rank=64, qk_nope_dim=hd, qk_rope_dim=16,
                      v_head_dim=hd)
        if self.sliding_window is not None:
            kw.update(sliding_window=min(self.sliding_window, 64))
        if self.rwkv_head_dim:
            kw.update(rwkv_head_dim=min(self.rwkv_head_dim, 32),
                      rwkv_lora_dim=8)
        if self.mrope:
            half = hd // 2
            a = half // 4
            b = (half - a) // 2
            kw.update(mrope_sections=(half - 2 * b, b, b))
        return self.replace(**kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # head
        for mixer, ffn in self.layer_specs:
            if mixer in ("attn", "local_attn"):
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                n += self.n_heads * hd * d
            elif mixer == "mla":
                n += d * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                n += d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                n += self.n_heads * self.v_head_dim * d
            elif mixer == "rwkv":
                n += 4 * d * d + d * d                # r,k,v,g,o
            elif mixer == "rglru":
                w = self.resolved_lru_width
                n += 2 * d * w + w * d + self.conv_width * w + 2 * w
            if ffn == "dense":
                n += 3 * d * self.d_ff
            elif ffn == "moe":
                n += self.n_experts * 3 * d * self.moe_d_ff
                n += self.n_shared_experts * 3 * d * self.moe_d_ff
                n += d * self.n_experts
            elif ffn == "rwkv_cm":
                n += 2 * d * self.d_ff
        n += 2 * self.n_layers * d + d                # norms
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE rooflines."""
        if not self.n_experts:
            return self.param_count()
        n = self.param_count()
        # subtract inactive expert FFNs
        per_exp = 3 * self.d_model * self.moe_d_ff
        n_moe_layers = sum(1 for _, f in self.layer_specs if f == "moe")
        n -= n_moe_layers * (self.n_experts - self.top_k) * per_exp
        return n


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicability(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, note).  Encodes the DESIGN.md skip rules."""
    if shape.kind == "decode":
        if not cfg.supports_decode:
            return False, "encoder-only: no decode step (DESIGN.md §6)"
        if shape.name == "long_500k" and not cfg.is_subquadratic:
            return True, "sliding-window variant (window=4096)"
    return True, ""


def long_context_variant(cfg: ArchConfig) -> ArchConfig:
    """Sub-quadratic variant used for long_500k on full-attention archs."""
    if cfg.is_subquadratic:
        return cfg
    return cfg.replace(sliding_window=4096)
