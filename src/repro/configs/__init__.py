"""Config registry + ShapeDtypeStruct input specs (no device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES,
                                shape_applicability, long_context_variant)
from repro.configs.archs import REGISTRY, ASSIGNED, get_config

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "REGISTRY", "ASSIGNED",
    "get_config", "input_specs", "shape_applicability", "long_context_variant",
]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, batch=None,
                seq_len=None) -> dict:
    """ShapeDtypeStruct stand-ins for every raw model input.

    The modality frontend for [audio]/[vlm] is a stub: the passive party's
    input is precomputed frame/patch embeddings of shape (B, S, d_model)
    rather than raw waveforms/pixels (DESIGN.md §6).
    """
    B = batch if batch is not None else shape.global_batch
    S = seq_len if seq_len is not None else shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        S_in = 1
    else:
        S_in = S
    specs = {}
    if cfg.frontend == "audio_frames":
        specs["tokens_p"] = _sds((B, S_in, cfg.d_model), act)
    elif cfg.frontend == "vision_patches":
        if shape.kind == "decode":
            # decode consumes text tokens; the vision prefix lives in the cache
            specs["tokens_p"] = _sds((B, S_in), "int32")
        else:
            n_vis = max(1, S_in // 4)
            specs["tokens_p"] = _sds((B, S_in - n_vis), "int32")
            specs["patches_p"] = _sds((B, n_vis, cfg.d_model), act)
    else:
        specs["tokens_p"] = _sds((B, S_in), "int32")
    # active party's private per-position features (f_a input)
    specs["x_a"] = _sds((B, S_in, cfg.d_active), act)
    if shape.kind == "train":
        specs["labels"] = _sds((B, S_in), "int32")
    return specs
