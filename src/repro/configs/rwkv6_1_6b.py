"""rwkv6-1.6b [ssm] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 — Finch data-dependent decay [arXiv:2404.05892]"""
from repro.configs.archs import RWKV6_16B as CONFIG

REDUCED = CONFIG.reduced()
