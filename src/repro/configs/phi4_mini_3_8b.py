"""phi4-mini-3.8b [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905]"""
from repro.configs.archs import PHI4_MINI as CONFIG

REDUCED = CONFIG.reduced()
