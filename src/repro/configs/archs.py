"""The 10 assigned architectures (exact sizes from the public pool) plus the
paper's own MLP/ResNet bottom models.  One ``make()`` per module in this
package re-exports from here so each arch also has its own file.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig

# ---------------------------------------------------------------------------
# [dense] Qwen2.5-14B — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family]
QWEN25_14B = ArchConfig(
    name="qwen2.5-14b", family="dense", source="hf:Qwen/Qwen2.5 family",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0,
)

# [dense] Minitron-8B — pruned Nemotron [arXiv:2407.14679]
MINITRON_8B = ArchConfig(
    name="minitron-8b", family="dense", source="arXiv:2407.14679",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000, rope_theta=500_000.0,
)

# [moe] DeepSeek-V2-Lite-16B — MLA kv_lora=512; 2 shared + 64 routed top-6
# [arXiv:2405.04434].  NOTE: the assignment line lists both "64e" and "160
# routed"; DeepSeek-V2-Lite is 64 routed experts (160 is full V2) — we use 64.
DEEPSEEK_V2_LITE = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944,                    # dense first-layer FFN (model card)
    vocab_size=102400,
    stages=((1, (("mla", "dense"),)), (26, (("mla", "moe"),))),
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    n_dense_layers=1,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    head_dim=192,                  # qk_nope + qk_rope
)

# [dense] Phi-4-mini-3.8B — RoPE SwiGLU GQA [arXiv:2412.08905]
PHI4_MINI = ArchConfig(
    name="phi4-mini-3.8b", family="dense", source="arXiv:2412.08905",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=200064, rope_theta=10_000.0,
)

# [audio] HuBERT-XLarge — encoder-only transformer backbone
# [arXiv:2106.07447]; conv feature frontend is a STUB (input_specs provides
# precomputed frame embeddings).  vocab = 504 k-means cluster targets.
HUBERT_XLARGE = ArchConfig(
    name="hubert-xlarge", family="audio", source="arXiv:2106.07447",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, causal=False, act="gelu",
    frontend="audio_frames",
)

# [moe] Qwen3-MoE-30B-A3B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]
QWEN3_MOE = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768,                      # == moe intermediate (all layers MoE)
    vocab_size=151936, rope_theta=1_000_000.0,
    stages=((48, (("attn", "moe"),)),),
    n_experts=128, n_shared_experts=0, top_k=8, moe_d_ff=768,
)

# [dense] Qwen2-0.5B — GQA, QKV bias [arXiv:2407.10671]
QWEN2_05B = ArchConfig(
    name="qwen2-0.5b", family="dense", source="arXiv:2407.10671",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
)

# [vlm] Qwen2-VL-2B — M-RoPE, dynamic resolution [arXiv:2409.12191];
# ViT encoder + projector are a STUB (precomputed patch embeddings).
QWEN2_VL_2B = ArchConfig(
    name="qwen2-vl-2b", family="vlm", source="arXiv:2409.12191",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, rope_theta=1_000_000.0,
    frontend="vision_patches", mrope=True, mrope_sections=(16, 24, 24),
    tie_embeddings=True,
)

# [ssm] RWKV6-1.6B "Finch" — data-dependent decay [arXiv:2404.05892]
RWKV6_16B = ArchConfig(
    name="rwkv6-1.6b", family="ssm", source="arXiv:2404.05892",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # 32 wkv heads of 64
    d_ff=7168, vocab_size=65536,
    stages=((24, (("rwkv", "rwkv_cm"),)),),
    rwkv_head_dim=64, rwkv_lora_dim=32,
)

# [hybrid] RecurrentGemma-9B — RG-LRU + local attention 1:2 [arXiv:2402.19427]
RECURRENTGEMMA_9B = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", source="arXiv:2402.19427",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, act="gelu",
    stages=(
        (12, (("rglru", "dense"), ("rglru", "dense"), ("local_attn", "dense"))),
        (1, (("rglru", "dense"), ("rglru", "dense"))),
    ),
    sliding_window=2048, lru_width=4096, conv_width=4,
)

# ---------------------------------------------------------------------------
# The paper's own bottom models (tabular; §5 of the paper).
# "mlp10" = ten-layer MLP bottom + two-layer MLP top; "resnet" = residual MLP.
PAPER_MLP = ArchConfig(
    name="paper-mlp10", family="tabular", source="PubSub-VFL §5.1",
    n_layers=10, d_model=128, n_heads=1, n_kv_heads=1, d_ff=128,
    vocab_size=0, stages=((10, (("attn", "dense"),)),),  # placeholder stages
)
PAPER_RESNET = ArchConfig(
    name="paper-resnet", family="tabular", source="PubSub-VFL §5.1",
    n_layers=18, d_model=256, n_heads=1, n_kv_heads=1, d_ff=256,
    vocab_size=0, stages=((18, (("attn", "dense"),)),),
)

REGISTRY = {
    c.name: c for c in [
        QWEN25_14B, MINITRON_8B, DEEPSEEK_V2_LITE, PHI4_MINI, HUBERT_XLARGE,
        QWEN3_MOE, QWEN2_05B, QWEN2_VL_2B, RWKV6_16B, RECURRENTGEMMA_9B,
        PAPER_MLP, PAPER_RESNET,
    ]
}

ASSIGNED = [
    "qwen2.5-14b", "minitron-8b", "deepseek-v2-lite-16b", "phi4-mini-3.8b",
    "hubert-xlarge", "qwen3-moe-30b-a3b", "qwen2-0.5b", "qwen2-vl-2b",
    "rwkv6-1.6b", "recurrentgemma-9b",
]


def get_config(name: str) -> ArchConfig:
    try:
        cfg = REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    if cfg.family != "tabular":
        cfg.validate()
    return cfg
