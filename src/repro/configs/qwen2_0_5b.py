"""qwen2-0.5b [dense] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA, QKV bias [arXiv:2407.10671]"""
from repro.configs.archs import QWEN2_05B as CONFIG

REDUCED = CONFIG.reduced()
