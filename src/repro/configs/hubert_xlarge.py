"""hubert-xlarge [audio] 48L d_model=1280 16H d_ff=5120 vocab=504 — encoder-only [arXiv:2106.07447]; conv frontend stubbed"""
from repro.configs.archs import HUBERT_XLARGE as CONFIG

REDUCED = CONFIG.reduced()
