"""deepseek-v2-lite-16b [moe] 27L d_model=2048 16H d_ff(moe)=1408 vocab=102400, MoE 64e top-6, MLA kv_lora=512, 2 shared experts [arXiv:2405.04434]"""
from repro.configs.archs import DEEPSEEK_V2_LITE as CONFIG

REDUCED = CONFIG.reduced()
