"""recurrentgemma-9b [hybrid] 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 — RG-LRU + local attn 1:2 [arXiv:2402.19427]"""
from repro.configs.archs import RECURRENTGEMMA_9B as CONFIG

REDUCED = CONFIG.reduced()
