"""minitron-8b [dense] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000 — pruned nemotron [arXiv:2407.14679]"""
from repro.configs.archs import MINITRON_8B as CONFIG

REDUCED = CONFIG.reduced()
