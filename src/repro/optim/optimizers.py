"""Minimal optax-free optimizers: SGD / momentum / Adam / AdamW.

API mirrors optax: opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply_updates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params,
                        updates)


def _resolve_lr(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: Optional[float] = None):
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum is not None:
            state["mu"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        del params
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        if momentum is None:
            ups = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32),
                               grads)
            return ups, {"step": step}
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        ups = jax.tree.map(lambda m: -lr_t * m, mu)
        return ups, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    """Adam (weight_decay>0 makes it AdamW; decoupled decay)."""
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) *
                          g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) *
                          jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        ups = jax.tree.map(upd, mu, nu,
                           params if params is not None else mu)
        return ups, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01):
    return adam(lr, b1, b2, eps, weight_decay)


def stack_states(states):
    """Stack per-replica pytrees (params or optimizer states) along a new
    leading replica axis — the layout the compiled replay engine vmaps."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def unstack_states(stack, n: int):
    """Inverse of `stack_states`: back to a list of per-replica pytrees."""
    return [jax.tree.map(lambda x: x[i], stack) for i in range(n)]


def _flatten_lanes(tree):
    """Flatten each lane's pytree into one contiguous f32 row: a tree with
    leaves (L, ...) becomes an (L, total) matrix plus an `unflatten`
    closure mapping such a matrix back to the original structure.  Leaf
    offsets are computed once at trace time from the static shapes."""
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape[1:])) for l in leaves]
    vec = jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1)
    offs = np.cumsum([0] + sizes)

    def unflatten(v):
        outs = [v[:, o:o + s].reshape(l.shape)
                for l, o, s in zip(leaves, offs, sizes)]
        return jax.tree.unflatten(treedef, outs)

    return vec, unflatten


def _flat_lane_step(opt: Optimizer, grads, state, params):
    """One optimizer step vmapped across the lane axis, with every lane's
    params/grads/moments flattened into ONE contiguous f32 vector.

    Single-leaf trees turn each of `opt.update`'s tree.maps into a single
    fused elementwise op over one buffer, so SGD/momentum/Adam execute as
    a handful of ops instead of ~2L per-leaf dispatches.  Only the update
    itself is flat — the carry keeps its pytree layout (the flat *state*
    layout regressed on XLA-CPU; see ROADMAP).  State entries mirroring
    the param tree (mu/nu) flatten alongside; scalar counters (step) pass
    through.  Falls back to the per-leaf path on non-f32 leaves, where
    concatenation would silently change the update dtype."""
    def per_leaf(g, s, p):
        ups, s2 = opt.update(g, s, p)
        return apply_updates(p, ups), s2

    if any(l.dtype != jnp.float32
           for l in jax.tree.leaves(params) + jax.tree.leaves(grads)):
        return jax.vmap(per_leaf)(grads, state, params)

    p_vec, unflatten_p = _flatten_lanes(params)
    g_vec, _ = _flatten_lanes(grads)
    pdef = jax.tree.structure(params)
    s_flat, s_unfl = {}, {}
    for k, v in state.items():
        if jax.tree.structure(v) == pdef:
            s_flat[k], s_unfl[k] = _flatten_lanes(v)
        else:
            s_flat[k] = v                      # e.g. the step counter

    def one(g, s, p):
        ups, s2 = opt.update(
            {"_": g},
            {k: ({"_": v} if k in s_unfl else v) for k, v in s.items()},
            {"_": p})
        return p + ups["_"], {k: (v["_"] if k in s_unfl else v)
                              for k, v in s2.items()}

    new_vec, new_flat = jax.vmap(one)(g_vec, s_flat, p_vec)
    new_state = {k: (s_unfl[k](v) if k in s_unfl else v)
                 for k, v in new_flat.items()}
    return unflatten_p(new_vec), new_state


def masked_replica_update(opt: Optimizer, grads, state, params, mask, *,
                          flat: bool = False):
    """One optimizer step vmapped across the replica axis, applied only on
    lanes where `mask` is True (no-op lanes keep params AND state, so their
    Adam step counters do not advance — identical to the event replay,
    where idle replicas simply do not step).  `flat=True` routes the step
    through the fused flat-vector path (`_flat_lane_step`)."""
    def one(g, s, p):
        ups, s2 = opt.update(g, s, p)
        return apply_updates(p, ups), s2

    if flat:
        new_params, new_state = _flat_lane_step(opt, grads, state, params)
    else:
        new_params, new_state = jax.vmap(one)(grads, state, params)

    def sel(new, old):
        m = mask.reshape(mask.shape + (1,) * (old.ndim - 1))
        return jnp.where(m, new, old)

    return (jax.tree.map(sel, new_params, params),
            jax.tree.map(sel, new_state, state))


def gather_replicas(stack, idx):
    """Gather per-lane pytrees `stack[idx[j]]` from a stacked-replica
    pytree (segment-style gather for the packed replay layout).  `idx`
    must be pre-clamped to valid replica indices."""
    return jax.tree.map(lambda x: x[idx], stack)


def scatter_replicas(stack, lanes, rep, mask, *, drop: bool = False):
    """Merge per-lane pytrees back into the replica stack:
    `stack[rep[j]] <- lanes[j]` where `mask[j]`.  Safe because the
    schedule compiler guarantees each replica appears at most once per
    phase per tick, so replica r is served by at most one lane.

    Two implementations:

    * ``drop=False`` (default) — a per-replica lane lookup + elementwise
      select rather than an XLA scatter: the select fuses into the
      surrounding update (like the dense layout's masked merge), whereas
      a scatter op forced a serialized copy of the whole stack on CPU
      when last measured (PR 2).
    * ``drop=True`` — a real ``.at[idx].set(..., mode="drop")`` scatter:
      masked-out lanes index one past the stack so XLA drops them.
      Under a donated scan carry the scatter can alias the stack
      in place instead of re-materializing n_rep × params per executed
      phase — the candidate win on accelerators the ROADMAP asks to
      re-measure (`benchmarks/replay_throughput.py` has the A/B entry:
      ``replay/micro_*_segmented_drop``)."""
    n = jax.tree.leaves(stack)[0].shape[0]
    if drop:
        idx = jnp.where(mask, jnp.maximum(rep, 0), n)   # n -> dropped
        return jax.tree.map(lambda x, l: x.at[idx].set(l, mode="drop"),
                            stack, lanes)
    hit = (rep[None, :] == jnp.arange(n)[:, None]) & mask[None, :]  # (n,L)
    found = hit.any(axis=1)
    lane_of = jnp.argmax(hit, axis=1)        # lane serving replica r

    def merge(x, l):
        sel = l[lane_of]                     # (n, ...) gather, L is tiny
        m = found.reshape((n,) + (1,) * (x.ndim - 1))
        return jnp.where(m, sel, x)

    return jax.tree.map(merge, stack, lanes)


def packed_replica_update(opt: Optimizer, grads, state, params, rep, mask,
                          *, flat: bool = False,
                          scatter_drop: bool = False):
    """One optimizer step on packed work lanes: gather each lane's replica
    params/state by index, step vmapped across lanes, scatter the results
    back by replica index.  Replicas not referenced by any valid lane keep
    params AND state (their Adam step counters do not advance) — identical
    to `masked_replica_update` on the dense layout, but executing only
    len(rep) lanes instead of the full replica stack.  `flat=True` routes
    the step through the fused flat-vector path (`_flat_lane_step`);
    `scatter_drop=True` merges back via the donation-aliased
    ``.at[].set(mode="drop")`` scatter instead of the where-merge (see
    `scatter_replicas`)."""
    idx = jnp.maximum(rep, 0)
    p_l = gather_replicas(params, idx)
    s_l = gather_replicas(state, idx)

    def one(g, s, p):
        ups, s2 = opt.update(g, s, p)
        return apply_updates(p, ups), s2

    if flat:
        new_p, new_s = _flat_lane_step(opt, grads, s_l, p_l)
    else:
        new_p, new_s = jax.vmap(one)(grads, s_l, p_l)
    return (scatter_replicas(params, new_p, rep, mask, drop=scatter_drop),
            scatter_replicas(state, new_s, rep, mask, drop=scatter_drop))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn
