"""PubSub-VFL core: the paper's contribution as a composable JAX system.

  channels     pub/sub broker (FIFO buffers p/q, waiting deadline) + the
               jit-safe ring-buffer twin
  semi_async   Eq. 5 ΔT_t schedule + PS aggregation
  cost_model   Eqs. 6-13 power-law delay/memory model (+ Table 8 fits)
  profiler     fits the model from timed probes of the real jitted ops
  planner      Algorithm 2 DP search (+ beyond-paper throughput objective)
  sim / des    deterministic discrete-event engine + the five runtimes
  trainer      replays DES event logs with real JAX updates
  jit_pipeline the whole two-party exchange inside one lax.scan
  runtime      one-call experiment API used by benchmarks/ and examples/
"""
