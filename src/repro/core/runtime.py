"""High-level experiment API: one call = one (method, dataset, config) run.

Couples: planner (optional) -> DES -> trainer replay -> metrics dict.
This is what benchmarks/ and examples/ call.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.cost_model import (CostConstants, PartyProfile,
                                   SystemProfile)
from repro.core.des import METHODS, RunConfig, SimResult, simulate
from repro.core.planner import Plan, plan
from repro.core.trainer import TrainResult, VFLTrainer
from repro.data.synthetic import Dataset, load
from repro.data.vertical import psi_align, vertical_split
from repro.dp.gdp import GDPConfig


@dataclass
class ExperimentConfig:
    method: str = "pubsub"
    dataset: str = "bank"
    scale: float = 0.05              # dataset size multiplier (CI-friendly)
    n_epochs: int = 5
    batch_size: int = 256
    w_a: int = 8
    w_p: int = 10
    cores_a: int = 32
    cores_p: int = 32
    features_active: Optional[int] = None   # data heterogeneity
    use_planner: bool = False        # let Algo. 2 pick (w_a, w_p, B)
    planner_objective: str = "throughput"  # "paper" = literal Eq. 14
    dp_mu: float = math.inf          # GDP privacy parameter
    seed: int = 0
    resnet: bool = False             # "large model" variant (Table 7)
    depth: int = 10
    # ablations
    disable_deadline: bool = False   # T_ddl = 0-like (w/o T_all)
    disable_semi_async: bool = False # sync every epoch (w/o ΔT)
    disable_planner: bool = False    # fixed equal workers (w/o DP algo)
    engine: str = "compiled"         # replay engine: "compiled" | "event"
    pack: str = "segmented"          # lane layout: "segmented"|"packed"|"dense"
    t_ddl: float = 10.0
    dt0: int = 5
    p: int = 5
    q: int = 5
    jitter: float = 0.10


def build_profile(cfg: ExperimentConfig, d_a: int, d_p: int
                  ) -> SystemProfile:
    ref = (d_a + d_p) / 2
    return SystemProfile(
        active=PartyProfile(cores=cfg.cores_a, feature_dim=d_a,
                            ref_feature_dim=ref),
        passive=PartyProfile(cores=cfg.cores_p, feature_dim=d_p,
                             ref_feature_dim=ref),
    )


def run_experiment(cfg: ExperimentConfig) -> Dict:
    ds = load(cfg.dataset, seed=cfg.seed, scale=cfg.scale)
    tr, te = ds.split(seed=cfg.seed)
    a_tr, p_tr = vertical_split(tr, seed=cfg.seed,
                                n_features_active=cfg.features_active)
    a_te, p_te = vertical_split(te, seed=cfg.seed,
                                n_features_active=cfg.features_active)
    a_tr, p_tr = psi_align(a_tr, p_tr)

    profile = build_profile(cfg, a_tr.X.shape[1], p_tr.X.shape[1])
    w_a, w_p, B = cfg.w_a, cfg.w_p, cfg.batch_size
    plan_obj: Optional[Plan] = None
    if cfg.use_planner and not cfg.disable_planner:
        plan_obj = plan(profile, w_a_range=(2, 16), w_p_range=(2, 16),
                        objective=cfg.planner_objective)
        w_a, w_p, B = plan_obj.w_a, plan_obj.w_p, plan_obj.batch_size
        B = max(min(B, a_tr.X.shape[0] // 2), 1)

    run_cfg = RunConfig(
        method=cfg.method, n_samples=a_tr.X.shape[0], batch_size=B,
        n_epochs=cfg.n_epochs, w_a=w_a, w_p=w_p, profile=profile,
        p=cfg.p, q=cfg.q,
        t_ddl=(0.0 if cfg.disable_deadline else cfg.t_ddl),
        dt0=cfg.dt0, jitter=cfg.jitter, seed=cfg.seed)
    sim = simulate(run_cfg)

    gdp = None
    if math.isfinite(cfg.dp_mu):
        gdp = GDPConfig(mu=cfg.dp_mu, clip=1.0,
                        minibatch=B, global_batch=B,
                        n_queries=run_cfg.n_batches * cfg.n_epochs)
    trainer = VFLTrainer(run_cfg, a_tr, p_tr, a_te, p_te, ds.task,
                         seed=cfg.seed, resnet=cfg.resnet, gdp=gdp,
                         depth=cfg.depth,
                         disable_semi_async=cfg.disable_semi_async)
    res = trainer.replay(sim, engine=cfg.engine, pack=cfg.pack)

    return {
        "method": cfg.method,
        "dataset": cfg.dataset,
        "task": ds.task,
        "metric": res.metric_name,
        "final": res.final_metric,
        "history": res.history,
        "losses": res.losses,
        "sim_s": sim.total_time,
        "sim_s_per_epoch": sim.total_time / max(cfg.n_epochs, 1),
        "cpu_util": sim.cpu_util,
        "waiting_per_epoch": sim.waiting_per_epoch,
        "comm_mb": sim.comm_mb,
        "staleness": res.staleness_mean,
        "lane_occupancy": res.lane_occupancy,
        "drops": sim.stats["drops"],
        "w_a": sim.stats["w_a"],
        "w_p": sim.stats["w_p"],
        "batch_size": B,
        "plan": (plan_obj.summary() if plan_obj else None),
    }


def time_to_target(result: Dict, target: float) -> float:
    """Simulated seconds to reach a target metric (AUC>=t or RMSE<=t)."""
    higher = result["metric"] == "auc"
    for i, v in enumerate(result["history"]):
        if (v >= target) if higher else (v <= target):
            return (i + 1) * result["sim_s_per_epoch"]
    return float("inf")
