"""Legacy one-shot experiment surface — now a thin wrapper over the
staged Session API (`repro.api.session`).

`run_experiment(cfg)` is kept for back-compat and returns the exact
pre-Session dict (same keys, same values for a fixed seed): it drives
`Session(cfg, reuse="exact").run()`, whose program cache keys on the
config seed, so nothing about the DES timetable or training math
changes — repeated identical configs simply stop re-paying data prep,
DES and compilation (the schedule memo already did most of that).

New code should use `repro.api` directly: staged artifacts, sweep reuse
(`run_sweep`), per-epoch callbacks and checkpoint-resume live there.
"""
from __future__ import annotations

from typing import Dict

from repro.api.session import (ExperimentConfig, Session,  # noqa: F401
                               build_profile)


def run_experiment(cfg: ExperimentConfig) -> Dict:
    """One (method, dataset, config) run -> the legacy metrics dict."""
    return Session(cfg, reuse="exact").run().metrics


def time_to_target(result: Dict, target: float) -> float:
    """Simulated seconds to reach a target metric (AUC>=t or RMSE<=t)."""
    higher = result["metric"] == "auc"
    for i, v in enumerate(result["history"]):
        if (v >= target) if higher else (v <= target):
            return (i + 1) * result["sim_s_per_epoch"]
    return float("inf")
