"""System-planning phase (paper §4.3, Algorithm 2).

Tabulated ("dynamic programming" in the paper's terminology) search over
the discrete state space (i, j, r) = (w_a, w_p, B) minimizing

    Cost(i,j,r) = max(T_comp_active, T_comp_passive) + (E+G)/B_b   (Eq. 14/15)

subject to the Eq. 13 memory bound B <= B_max.  Privacy: only each party's
*profile* (fitted constants, core counts, memory) enters — never data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_model import CostModel, SystemProfile

DEFAULT_BATCHES = (16, 32, 64, 128, 256, 512, 1024)


@dataclass(frozen=True)
class PlanTable:
    """The full Algorithm-2 cost tabulation with labeled axes: entry
    ``costs[i, j, r]`` is the modeled cost at ``w_a = was[i]``,
    ``w_p = wps[j]``, ``batch_size = batches[r]`` (np.inf where the
    configuration is infeasible — core caps or the Eq. 13 memory
    bound)."""
    was: Tuple[int, ...]
    wps: Tuple[int, ...]
    batches: Tuple[int, ...]
    costs: np.ndarray                    # (len(was), len(wps), len(batches))

    def argmin(self) -> Tuple[int, int, int]:
        """The (w_a, w_p, batch_size) labels of the cheapest entry."""
        i, j, r = np.unravel_index(int(np.argmin(self.costs)),
                                   self.costs.shape)
        return self.was[i], self.wps[j], self.batches[r]


@dataclass(frozen=True)
class Plan:
    w_a: int
    w_p: int
    batch_size: int
    cost: float
    b_max: float
    table: Optional[PlanTable] = None    # full tabulation (keep_table=True)

    def summary(self) -> str:
        return (f"plan: w_a={self.w_a} w_p={self.w_p} B={self.batch_size} "
                f"cost/iter={self.cost:.4f}s (B_max={self.b_max:.0f})")


def plan(profile: SystemProfile, *,
         w_a_range: Tuple[int, int] = (2, 50),
         w_p_range: Tuple[int, int] = (2, 50),
         batch_sizes: Sequence[int] = DEFAULT_BATCHES,
         keep_table: bool = False,
         objective: str = "paper") -> Plan:
    """Algorithm 2: exhaustive DP tabulation + argmin.

    objective="paper": the literal Eq. 14/15 per-iteration cost.  NOTE:
    this prefers the smallest feasible batch (per-iteration latency falls
    with B even though epoch time rises) — a limitation of the printed
    formulation.
    objective="throughput" (beyond-paper, EXPERIMENTS.md §Perf): minimize
    steady-state pipelined *per-sample* time
        max(T_A(w_a,B)/w_a, T_P(w_p,B)/w_p) / B,
    which matches what the Pub/Sub runtime actually sustains and recovers
    the paper's own chosen configs (B=256-ish, mid-size worker pools).
    """
    cm = CostModel(profile)
    b_max = cm.b_max()
    feasible = [b for b in batch_sizes if b <= b_max]
    if not feasible:
        feasible = [min(batch_sizes)]
    was = list(range(w_a_range[0], w_a_range[1] + 1))
    wps = list(range(w_p_range[0], w_p_range[1] + 1))
    table = np.full((len(was), len(wps), len(feasible)), np.inf)
    best = (np.inf, None)
    for i, wa in enumerate(was):
        if wa > profile.active.cores:
            continue
        for j, wp in enumerate(wps):
            if wp > profile.passive.cores:
                continue
            for r, B in enumerate(feasible):
                if objective == "paper":
                    cost = cm.objective(wa, wp, B)
                else:   # steady-state pipelined per-sample time
                    t_a = (cm.t_f_a(B, wa) + cm.t_b_a(B, wa) +
                           cm.t_top_a(B, wa))
                    t_p = cm.t_f_p(B, wp) + cm.t_b_p(B, wp)
                    cost = max(t_a / wa, t_p / wp) / B
                    # PS coordination overhead grows with the pool size
                    # (aggregation fan-in + staleness control)
                    cost *= 1.0 + 0.01 * (wa + wp)
                table[i, j, r] = cost
                if cost < best[0]:
                    best = (cost, (wa, wp, B))
    assert best[1] is not None, "no feasible configuration"
    wa, wp, B = best[1]
    plan_table = PlanTable(was=tuple(was), wps=tuple(wps),
                           batches=tuple(feasible), costs=table) \
        if keep_table else None
    return Plan(wa, wp, B, best[0], b_max, plan_table)


def plan_multiparty(profiles: List[SystemProfile], **kw) -> Plan:
    """Appendix-H extension: plan jointly against the *weakest* passive
    party (the bottleneck insight from the paper's multi-party discussion)."""
    def weakness(p: SystemProfile) -> float:
        return CostModel(p).t_passive(256, 8)
    weakest = max(profiles, key=weakness)
    return plan(weakest, **kw)
