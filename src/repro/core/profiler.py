"""System profiling (paper §4.2 + Appendix H empirical experiments).

Times the actual jitted VFL ops over a batch-size grid on this host and
fits the per-sample power law  t/B = lambda * B^gamma  by least squares in
log-log space — the same procedure that produced the paper's Table 8.
Each party profiles only its OWN ops; only the fitted constants (the
"system profile") are shared, never data (privacy constraint §4.2).
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import CostConstants
from repro.models import tabular


def fit_power_law(batch_sizes: Sequence[int], per_batch_times:
                  Sequence[float]) -> Tuple[float, float]:
    """Fit t_batch = lam * B^(1+gam)  (i.e. per-sample t/B = lam * B^gam).

    Returns (lam, gam)."""
    B = np.asarray(batch_sizes, dtype=np.float64)
    t = np.asarray(per_batch_times, dtype=np.float64)
    y = np.log(np.maximum(t / B, 1e-12))
    x = np.log(B)
    A = np.stack([np.ones_like(x), x], axis=1)
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    return float(np.exp(coef[0])), float(coef[1])


def _time_fn(fn, *args, reps: int = 3, **kw) -> float:
    fn(*args, **kw)                     # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def profile_host(d_a: int = 24, d_p: int = 24, depth: int = 10,
                 batch_sizes: Sequence[int] = (16, 32, 64, 128, 256),
                 seed: int = 0) -> Tuple[CostConstants, Dict]:
    """Measure forward/backward times of the real ops on this host and
    return fitted CostConstants (+ the raw probe table for Fig. 8)."""
    key = jax.random.PRNGKey(seed)
    ka, kp, kt = jax.random.split(key, 3)
    theta_p = tabular.init_bottom(kp, d_p, depth=depth)
    theta_a = {"bottom": tabular.init_bottom(ka, d_a, depth=depth),
               "top": tabular.init_top(kt)}
    rows: Dict[str, List[float]] = {"B": [], "t_f_p": [], "t_b_p": [],
                                    "t_f_a": [], "t_top": []}
    for B in batch_sizes:
        xa = jnp.ones((B, d_a), jnp.float32)
        xp = jnp.ones((B, d_p), jnp.float32)
        y = jnp.zeros((B,), jnp.float32)
        z = tabular.passive_forward(theta_p, xp)
        g_z = jnp.ones_like(z)
        t_fp = _time_fn(tabular.passive_forward, theta_p, xp)
        t_bp = _time_fn(tabular.passive_backward, theta_p, xp, g_z)
        t_as = _time_fn(tabular.active_step, theta_a, xa, z, y,
                        task="regression")
        rows["B"].append(B)
        rows["t_f_p"].append(t_fp)
        rows["t_b_p"].append(t_bp)
        # split the active step into bottom-forward ~ t_fp-like and the rest
        rows["t_f_a"].append(t_fp)          # same bottom architecture
        rows["t_top"].append(max(t_as - t_fp - t_bp, 1e-6))
    lam_p, gam_p = fit_power_law(rows["B"], rows["t_f_p"])
    phi_p, bet_p = fit_power_law(rows["B"], rows["t_b_p"])
    lam_a, gam_a = fit_power_law(rows["B"], rows["t_f_a"])
    phi_t, bet_t = fit_power_law(rows["B"], rows["t_top"])
    consts = CostConstants(
        lambda_a=lam_a, gamma_a=gam_a, lambda_p=lam_p, gamma_p=gam_p,
        varphi_a=phi_p, beta_a=bet_p, varphi_p=phi_p, beta_p=bet_p,
        lambda_a_top=phi_t / 2, gamma_a_top=bet_t,
        varphi_a_top=phi_t / 2, beta_a_top=bet_t,
    )
    return consts, rows
