"""The Pub/Sub mechanism as a pure-JAX composable (deliverable (a)).

`pipelined_train` runs the whole two-party semi-asynchronous exchange
INSIDE one jitted lax.scan: the passive party publishes cut-layer
embeddings into a fixed-size ring buffer (the jit twin of the embedding
channel, `core.channels.channel_*`); the active party consumes the entry
published `lag` steps earlier (bounded staleness = the paper's buffer
depth p); the gradient channel is the symmetric ring.  This is the
TPU-native rendering of Algorithm 1: on hardware the two halves live on
the two pods and the rings are the only pod-crossing traffic.

Semantics match core.trainer's replay: the active step differentiates
w.r.t. the STALE embedding; the passive backward applies that cotangent
through a fresh forward at its CURRENT params (delayed-gradient descent,
Assumption D.4 of the paper's proof).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import tabular
from repro.optim.optimizers import adam, apply_updates


def pipelined_train(theta_a, theta_p, xa_steps, xp_steps, y_steps, *,
                    lag: int = 2, lr: float = 1e-3, task: str,
                    dp_sigma: float = 0.0, dp_clip: float = 1e9,
                    rng=None):
    """xa/xp/y_steps: (n_steps, B, ·) pre-batched streams.

    Returns (theta_a, theta_p, losses (n_steps,)) — losses are NaN for the
    first `lag` warmup steps (channel not yet filled)."""
    n_steps, B = xp_steps.shape[0], xp_steps.shape[1]
    d_emb = tabular.passive_forward(theta_p, xp_steps[0]).shape[-1]
    opt = adam(lr)
    opt_a = opt.init(theta_a)
    opt_p = opt.init(theta_p)
    if rng is None:
        rng = jax.random.PRNGKey(0)

    # embedding channel ring: z + the step index it belongs to
    ring_z = jnp.zeros((lag, B, d_emb), jnp.float32)

    def step(carry, inp):
        theta_a, theta_p, opt_a, opt_p, ring_z, t, rng = carry
        xa, xp, y = inp
        rng, sub = jax.random.split(rng)

        # --- passive worker: forward + publish (Algorithm 1 l.6-10) ---
        z = tabular.passive_forward(theta_p, xp)
        nrm = jnp.linalg.norm(z, axis=-1, keepdims=True)
        z_pub = z * jnp.minimum(1.0, dp_clip / jnp.maximum(nrm, 1e-12))
        if dp_sigma > 0:
            z_pub = z_pub + dp_sigma * jax.random.normal(sub, z.shape)
        slot = t % lag
        ring_z_new = jax.lax.dynamic_update_index_in_dim(
            ring_z, z_pub, slot, 0)

        # --- active worker: consume the entry published `lag-1` ago ---
        stale_slot = (t + 1) % lag            # oldest surviving entry
        z_stale = jax.lax.dynamic_index_in_dim(ring_z_new, stale_slot, 0,
                                               keepdims=False)
        loss, g_a, g_z = tabular.active_step(theta_a, xa, z_stale, y,
                                             task=task)
        ups_a, opt_a = opt.update(g_a, opt_a, theta_a)
        theta_a = apply_updates(theta_a, ups_a)

        # --- passive backward: delayed cotangent at CURRENT params ---
        g_p = tabular.passive_backward(theta_p, xp, g_z)
        ups_p, opt_p = opt.update(g_p, opt_p, theta_p)
        theta_p = apply_updates(theta_p, ups_p)

        warm = t >= lag - 1
        loss = jnp.where(warm, loss, jnp.nan)
        return (theta_a, theta_p, opt_a, opt_p, ring_z_new, t + 1, rng), \
            loss

    (theta_a, theta_p, *_), losses = jax.lax.scan(
        step,
        (theta_a, theta_p, opt_a, opt_p, ring_z, jnp.zeros((), jnp.int32),
         rng),
        (xa_steps, xp_steps, y_steps))
    return theta_a, theta_p, losses
