"""Jit-native renderings of the Pub/Sub exchange.

Two engines live here:

1. `pipelined_train` — the original single-pair demo: the whole two-party
   semi-asynchronous exchange inside one jitted lax.scan, with the
   embedding/gradient rings as the only "pod-crossing" traffic.

2. `CompiledReplayEngine` — the production replay engine.  It executes a
   `core.schedule.CompiledSchedule` (a DES event log lowered to dense
   per-tick arrays; see docs/architecture.md for the format) as jitted
   ``lax.scan`` work per epoch segment — one scan over the padded tick
   program (``pack="dense"``/``"packed"``), or one jitted epoch runner
   chaining per-run scans with **cond-free per-signature tick bodies**
   (``pack="segmented"``, the default):

   * per-replica params and optimizer states are stacked into
     leading-axis pytrees; every tick **vmaps** the passive forwards,
     passive backwards and active steps across lanes.  In the legacy
     ``pack="dense"`` layout a lane IS a replica and no-op lanes are
     masked out (`optim.masked_replica_update`); in the ``"packed"``
     layout a lane is a *work row* carrying an explicit
     replica index — the engine gathers each lane's params from the
     stacked pytrees and scatters updates back by replica index
     (`optim.packed_replica_update`), so only occupied lanes execute
     (≥90% executed-lane occupancy on pubsub logs vs. ~55% dense);
   * the ``"segmented"`` layout executes the same packed work rows as
     signature runs: each run's body statically traces only the phases
     the run uses, removing the per-phase ``lax.cond``s and their
     whole-carry branch-unification copies (~1.3x steady-state epoch
     speedup over packed at B=256 on CPU); the optimizer step can
     further run **flat** — each lane's pytrees flattened to one
     contiguous f32 vector so the update is a handful of fused
     elementwise ops (`optim.optimizers._flat_lane_step`; default on
     only off-CPU, where the flatten copies are not the bottleneck);
   * in-flight embeddings/gradients live in device-resident slot rings
     (`core.channels.slot_ring_*`) — the compiler has already resolved
     FIFO order, eviction and peak occupancy into explicit slot indices;
   * the DP publish (projection+tanh+L2-clip+Gaussian noise) runs fused
     on device via `models.tabular.publish_embedding` — the Pallas
     `cut_layer` kernel on TPU, its jnp reference elsewhere — with noise
     drawn from a PRNG key threaded through the scan carry;
   * `vfl_ps` round aggregations are per-tick flags folded into the scan
     carry; `avfl_ps`/`pubsub` Eq. 5 sync-mark aggregations run between
     segments; per-epoch losses accumulate on device and cross to the
     host exactly once, at the end of the replay;
   * the scan carry is donated back to the runtime (`donate_argnums`) on
     accelerators, so params/opt buffers are updated in place;
   * a structural sweep group can run **point-stacked**: the cached
     epoch runners are reused vmapped over a new leading point axis
     (`run_epoch_stacked` — per-point params/opt/rings/DP-keys and
     per-point {lr, clip, sigma} vectors, one broadcast tick schedule),
     so N same-shape training runs execute as ONE device program and
     pay the per-tick dispatch/fixed costs once (`api.sweep
     run_sweep(stacked=True)`; `stack_points`/`point_state` convert
     between stacked and single-run `TrainerState`s).

   Jitted runners are cached process-wide per engine spec, so many
   trainer instances (e.g. a benchmark sweep) share one compilation per
   (method-flags, shapes) pair.  Across processes, engine construction
   enables the persistent XLA compilation cache (`core.xla_cache`), so
   sweeps and CI pay each (spec, shapes) compile once per machine.

Semantics match core.trainer's event replay exactly: the active step
differentiates w.r.t. the STALE published embedding; the passive backward
applies that cotangent through a fresh forward at its CURRENT params
(delayed-gradient descent, Assumption D.4 of the paper's proof); the
schedule compiler preserves every per-replica event order, so losses and
final params agree with the event loop to float tolerance.
"""
from __future__ import annotations

import math
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, replace as _dc_replace
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh_replay
from repro.core.channels import (slot_ring_init, slot_ring_read,
                                 slot_ring_write)
from repro.core.schedule import CompiledSchedule, device_lower
from repro.data.shards import is_feature_source
from repro.core.xla_cache import enable_persistent_cache
from repro.models import tabular
from repro.optim.optimizers import (adam, apply_updates, gather_replicas,
                                    masked_replica_update,
                                    packed_replica_update, stack_states,
                                    unstack_states)


def pipelined_train(theta_a, theta_p, xa_steps, xp_steps, y_steps, *,
                    lag: int = 2, lr: float = 1e-3, task: str,
                    dp_sigma: float = 0.0, dp_clip: float = 1e9,
                    rng=None):
    """xa/xp/y_steps: (n_steps, B, ·) pre-batched streams.

    Returns (theta_a, theta_p, losses (n_steps,)) — losses are NaN for the
    first `lag` warmup steps (channel not yet filled)."""
    n_steps, B = xp_steps.shape[0], xp_steps.shape[1]
    d_emb = tabular.passive_forward(theta_p, xp_steps[0]).shape[-1]
    opt = adam(lr)
    opt_a = opt.init(theta_a)
    opt_p = opt.init(theta_p)
    if rng is None:
        rng = jax.random.PRNGKey(0)

    # embedding channel ring: z + the step index it belongs to
    ring_z = jnp.zeros((lag, B, d_emb), jnp.float32)

    def step(carry, inp):
        theta_a, theta_p, opt_a, opt_p, ring_z, t, rng = carry
        xa, xp, y = inp
        rng, sub = jax.random.split(rng)

        # --- passive worker: forward + publish (Algorithm 1 l.6-10) ---
        z = tabular.passive_forward(theta_p, xp)
        nrm = jnp.linalg.norm(z, axis=-1, keepdims=True)
        z_pub = z * jnp.minimum(1.0, dp_clip / jnp.maximum(nrm, 1e-12))
        if dp_sigma > 0:
            z_pub = z_pub + dp_sigma * jax.random.normal(sub, z.shape)
        slot = t % lag
        ring_z_new = jax.lax.dynamic_update_index_in_dim(
            ring_z, z_pub, slot, 0)

        # --- active worker: consume the entry published `lag-1` ago ---
        stale_slot = (t + 1) % lag            # oldest surviving entry
        z_stale = jax.lax.dynamic_index_in_dim(ring_z_new, stale_slot, 0,
                                               keepdims=False)
        loss, g_a, g_z = tabular.active_step(theta_a, xa, z_stale, y,
                                             task=task)
        ups_a, opt_a = opt.update(g_a, opt_a, theta_a)
        theta_a = apply_updates(theta_a, ups_a)

        # --- passive backward: delayed cotangent at CURRENT params ---
        g_p = tabular.passive_backward(theta_p, xp, g_z)
        ups_p, opt_p = opt.update(g_p, opt_p, theta_p)
        theta_p = apply_updates(theta_p, ups_p)

        warm = t >= lag - 1
        loss = jnp.where(warm, loss, jnp.nan)
        return (theta_a, theta_p, opt_a, opt_p, ring_z_new, t + 1, rng), \
            loss

    (theta_a, theta_p, *_), losses = jax.lax.scan(
        step,
        (theta_a, theta_p, opt_a, opt_p, ring_z, jnp.zeros((), jnp.int32),
         rng),
        (xa_steps, xp_steps, y_steps))
    return theta_a, theta_p, losses


# ===========================================================================
# compiled replay engine
# ===========================================================================
class StagingError(RuntimeError):
    """A background staging failure (host gather / device_put in the
    windowed double-buffer thread), re-raised on the replay thread as
    the epoch's exception with the original chained via ``__cause__``."""


def replica_mean(stack, perm: Optional[Tuple[int, ...]] = None):
    """PS aggregation over the stacked replica axis.

    Unrolled in the same left-to-right order as `semi_async.aggregate`
    so the compiled and event engines agree bit-for-bit.  Under a
    device-lowered lane layout (`schedule.device_lower`) the real
    replicas sit at permuted lanes with padding in between: `perm` lists
    their lanes in ORIGINAL replica order, so the unrolled add chain —
    and hence the float rounding — is identical to the single-device
    program, and padding lanes never enter the mean."""
    def leaf(x):
        if perm is not None:
            # gather the real lanes into a contiguous stack FIRST, then
            # run the exact perm=None chain on it.  Summing via
            # per-element indexing of the padded stack instead is NOT
            # safe on a mesh run: the partitioner/codegen contracts that
            # chain differently over a lane-sharded operand (~1 ULP off
            # the single-device rounding), while a gather followed by
            # the canonical contiguous chain compiles bit-identically.
            x = x[jnp.asarray(perm, jnp.int32)]
        n = x.shape[0]
        w = 1.0 / n
        acc = x[0] * w
        for i in range(1, n):
            acc = acc + x[i] * w
        return acc
    return jax.tree.map(leaf, stack)


def _broadcast_mean(stack, perm: Optional[Tuple[int, ...]] = None):
    """Aggregate + broadcast: every replica receives the averaged params.
    Padding lanes receive it too — they are inert (no work row ever
    names them), so overwriting them is harmless and keeps the broadcast
    a plain full-axis write."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(replica_mean(x, perm),
                                   x.shape).astype(x.dtype),
        stack)


def _live_broadcast_mean(stack, perm: Tuple[int, ...], mask):
    """Subset PS aggregation for faulty boundaries: mean over the `perm`
    lanes (live replicas, canonical order, the exact `replica_mean`
    gather-first chain — bitwise equal to `semi_async.aggregate` over
    the same subset, and mesh-safe for the same reason), written back to
    exactly the `mask` lanes.  Every other lane — a crashed replica
    frozen through its outage, mesh padding — passes through untouched,
    which is what lets a rejoining replica pull the survivor mean at a
    later boundary while preserving the healthy lanes' bit pattern."""
    idx = jnp.asarray(perm, jnp.int32)
    m = jnp.asarray(mask)

    def leaf(x):
        g = x[idx]
        w = 1.0 / g.shape[0]
        acc = g[0] * w
        for i in range(1, g.shape[0]):
            acc = acc + g[i] * w
        keep = m.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(keep,
                         jnp.broadcast_to(acc, x.shape).astype(x.dtype), x)
    return jax.tree.map(leaf, stack)


@dataclass(frozen=True)
class EngineSpec:
    """Static configuration of the compiled engine; the process-wide
    runner cache is keyed on this (plus an opt cache key), so repeated
    trainer instances reuse one compilation per spec+shapes.

    Hyperparameters that only scale arithmetic — the learning rate and
    the DP clip/sigma *values* — are deliberately NOT part of the spec:
    they enter the jitted runners as runtime scalars (the `hyper` dict),
    so a sweep varying lr or dp_mu reuses one XLA program.  Only the DP
    *structure* is static: `dp` selects the fused publish path, `noise`
    whether a PRNG draw is traced at all."""
    n_rep_a: int
    n_rep_p: int
    task: str
    resnet: bool
    dp: bool                  # fused clip+noise publish traced
    noise: bool               # Gaussian noise drawn (sigma > 0)
    has_inscan_agg: bool
    use_pallas: bool
    donate: bool
    pack: str = "dense"
    flat_opt: bool = False    # fused flat optimizer update (segmented)
    scatter_drop: bool = False  # .at[].set(mode="drop") replica scatter
    # device-lowered lane layouts only: real replicas' lanes in original
    # replica order (None = identity, the single-device layout — so a
    # divisible mesh run shares the single-device runner cache entry)
    agg_perm_a: Optional[Tuple[int, ...]] = None
    agg_perm_p: Optional[Tuple[int, ...]] = None


class TrainerState(NamedTuple):
    """The complete, explicit training state of a compiled replay — an
    immutable pytree that round-trips through `checkpoint.store`
    (`save_state`/`restore_state`) for mid-training save/resume.

    Fields 0..8 are the jitted scan carry (stacked-replica params and
    optimizer states, the in-flight embedding/gradient rings, the
    device-resident per-epoch loss accumulators, and the DP PRNG key);
    `epoch` counts completed epochs host-side and is what makes a
    restored state resumable at the right segment.  `window` counts
    completed staging windows *within* the current epoch on the
    streaming data path (always 0 at epoch boundaries and on the
    resident path), so a checkpoint taken mid-epoch resumes on the
    correct window."""
    theta_a: Any
    opt_a: Any
    theta_p: Any
    opt_p: Any
    ring_e: Any
    ring_g: Any
    loss_vec: Any
    cnt_vec: Any
    key: Any
    epoch: int = 0
    window: int = 0

    @property
    def carry(self) -> tuple:
        return tuple(self)[:9]


_RUNNER_CACHE: Dict[tuple, object] = {}


def _phase_ops(spec: EngineSpec):
    def p_backward(th, x, gz):
        return tabular.passive_backward(th, x, gz, resnet=spec.resnet)

    def a_step(th, x, z, y):
        return tabular.active_step(th, x, z, y, task=spec.task,
                                   resnet=spec.resnet)

    def publish(th, x, nz, clip, sigma):
        if not spec.dp:
            return tabular.passive_forward(th, x, resnet=spec.resnet)
        return tabular.publish_embedding(th, x, nz, clip=clip,
                                         sigma=sigma,
                                         resnet=spec.resnet,
                                         use_pallas=spec.use_pallas,
                                         dynamic=True)

    return p_backward, a_step, publish


def _agg_fns(spec: EngineSpec, *, on_mesh: bool = False):
    """The two aggregation branches, lane-permutation aware.

    ``on_mesh=True`` forces the gather-first formulation even when no
    lane permutation is attached (perm None): per-element indexing of a
    lane-sharded stack lets the partitioner contract the mean chain
    differently from the single-device program (~1 ULP), while a gather
    into a contiguous stack followed by the canonical left-to-right
    chain compiles bit-identically on both.  Lowered schedules always
    carry a non-identity lane map these days (see `slab_plan`), so the
    forcing is a backstop rather than the common path."""
    pa, pp = spec.agg_perm_a, spec.agg_perm_p
    if on_mesh:
        pa = pa if pa is not None else tuple(range(spec.n_rep_a))
        pp = pp if pp is not None else tuple(range(spec.n_rep_p))
    return (lambda s: _broadcast_mean(s, pa),
            lambda s: _broadcast_mean(s, pp))


def _make_dense_tick(spec: EngineSpec):
    p_backward, a_step, publish = _phase_ops(spec)
    bm_a, bm_p = _agg_fns(spec)

    def tick(carry, xs, data, opt, hyper):
        rows_tab, Xa, Xp, Y = data
        clip, sigma = hyper["clip"], hyper["sigma"]
        ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key = carry

        # each phase runs under a lax.cond on "any lane active": padded /
        # sparse ticks skip the whole vmapped pass at runtime (the DES
        # leaves many ticks with an idle party)

        # --- phase 1a: passive backwards (consume the gradient ring) ---
        pb_mask = xs["pb_bid"] >= 0

        def pb_phase(args):
            tp, op_ = args
            xb = Xp[rows_tab[jnp.maximum(xs["pb_bid"], 0)]]
            g_in = slot_ring_read(ring_g, xs["pb_slot"])
            grads_p = jax.vmap(p_backward)(tp, xb, g_in)
            return masked_replica_update(opt, grads_p, op_, tp, pb_mask,
                                         flat=spec.flat_opt)

        tp, op_ = jax.lax.cond(jnp.any(pb_mask), pb_phase,
                               lambda args: args, (tp, op_))

        # --- phase 1b: passive forwards, DP-publish to embedding ring ---
        pf_mask = xs["pf_bid"] >= 0
        if spec.noise:
            key, sub = jax.random.split(key)

        def pf_phase(ring_e):
            xf = Xp[rows_tab[jnp.maximum(xs["pf_bid"], 0)]]
            if spec.noise:
                noise = jax.random.normal(
                    sub, xf.shape[:2] + (ring_e.shape[-1],), jnp.float32)
                z_pub = jax.vmap(
                    lambda th, x, nz: publish(th, x, nz, clip, sigma))(
                        tp, xf, noise)
            else:
                z_pub = jax.vmap(
                    lambda th, x: publish(th, x, None, clip, sigma))(tp, xf)
            return slot_ring_write(ring_e, xs["pf_slot"], z_pub, pf_mask)

        ring_e = jax.lax.cond(jnp.any(pf_mask), pf_phase,
                              lambda r: r, ring_e)

        # --- phase 2: active steps (consume ring, produce cotangents) ---
        as_mask = xs["as_bid"] >= 0

        def as_phase(args):
            ta, oa, ring_g, loss_vec, cnt_vec = args
            a_rows = rows_tab[jnp.maximum(xs["as_bid"], 0)]
            z_in = slot_ring_read(ring_e, xs["as_eslot"])
            loss, g_a, g_z = jax.vmap(a_step)(ta, Xa[a_rows], z_in,
                                              Y[a_rows])
            ta, oa = masked_replica_update(opt, g_a, oa, ta, as_mask,
                                           flat=spec.flat_opt)
            ring_g = slot_ring_write(ring_g, xs["as_gslot"], g_z, as_mask)
            loss_vec = loss_vec.at[xs["as_epoch"]].add(
                jnp.where(as_mask, loss, 0.0))
            cnt_vec = cnt_vec.at[xs["as_epoch"]].add(
                as_mask.astype(jnp.float32))
            return ta, oa, ring_g, loss_vec, cnt_vec

        ta, oa, ring_g, loss_vec, cnt_vec = jax.lax.cond(
            jnp.any(as_mask), as_phase, lambda args: args,
            (ta, oa, ring_g, loss_vec, cnt_vec))

        # --- in-scan PS aggregation (vfl_ps round barriers) ---
        if spec.has_inscan_agg:
            ta = jax.lax.cond(xs["agg_a"], bm_a, lambda s: s, ta)
            tp = jax.lax.cond(xs["agg_p"], bm_p, lambda s: s, tp)

        return (ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key)

    return tick


def _make_packed_tick(spec: EngineSpec):
    """Tick body for the packed work-row layout: each lane carries a
    replica index; phases gather per-lane params from the stacked
    replica pytrees and merge updates back by index
    (`optim.packed_replica_update`), so only occupied lanes execute.
    Phase order (pb, pf, as) and all ring/aggregation semantics are
    identical to the dense tick."""
    p_backward, a_step, publish = _phase_ops(spec)
    bm_a, bm_p = _agg_fns(spec)

    def tick(carry, xs, data, opt, hyper):
        rows_tab, Xa, Xp, Y = data
        clip, sigma = hyper["clip"], hyper["sigma"]
        ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key = carry

        # the two passive sub-phases share ONE lax.cond: packed ticks
        # rarely have an idle passive party, and every extra cond costs a
        # whole-carry copy per tick to unify its branches (the dominant
        # per-tick overhead at packed lane widths).  Within the phase the
        # backward runs before the forward, so a p_fwd fused onto its
        # replica's p_bwd tick publishes at the freshly updated params —
        # exactly the event order the schedule compiler promised.
        pb_mask = xs["pb_rep"] >= 0
        pf_mask = xs["pf_rep"] >= 0
        if spec.noise:
            key, sub = jax.random.split(key)

        def passive_phase(args):
            tp, op_, ring_e = args
            # --- phase 1a: passive backwards (consume the grad ring) ---
            tp_l = gather_replicas(tp, jnp.maximum(xs["pb_rep"], 0))
            xb = Xp[rows_tab[jnp.maximum(xs["pb_bid"], 0)]]
            g_in = slot_ring_read(ring_g, xs["pb_slot"])
            grads_p = jax.vmap(p_backward)(tp_l, xb, g_in)
            tp, op_ = packed_replica_update(opt, grads_p, op_, tp,
                                            xs["pb_rep"], pb_mask,
                                            flat=spec.flat_opt,
                                            scatter_drop=spec.scatter_drop)
            # --- phase 1b: passive forwards, DP-publish to the ring ---
            tp_f = gather_replicas(tp, jnp.maximum(xs["pf_rep"], 0))
            xf = Xp[rows_tab[jnp.maximum(xs["pf_bid"], 0)]]
            if spec.noise:
                noise = jax.random.normal(
                    sub, xf.shape[:2] + (ring_e.shape[-1],), jnp.float32)
                z_pub = jax.vmap(
                    lambda th, x, nz: publish(th, x, nz, clip, sigma))(
                        tp_f, xf, noise)
            else:
                z_pub = jax.vmap(
                    lambda th, x: publish(th, x, None, clip, sigma))(tp_f,
                                                                     xf)
            ring_e = slot_ring_write(ring_e, xs["pf_slot"], z_pub, pf_mask)
            return tp, op_, ring_e

        tp, op_, ring_e = jax.lax.cond(
            jnp.any(pb_mask) | jnp.any(pf_mask), passive_phase,
            lambda args: args, (tp, op_, ring_e))

        # --- phase 2: active steps (consume ring, produce cotangents) ---
        as_mask = xs["as_rep"] >= 0

        def as_phase(args):
            ta, oa, ring_g, loss_vec, cnt_vec = args
            ta_l = gather_replicas(ta, jnp.maximum(xs["as_rep"], 0))
            a_rows = rows_tab[jnp.maximum(xs["as_bid"], 0)]
            z_in = slot_ring_read(ring_e, xs["as_eslot"])
            loss, g_a, g_z = jax.vmap(a_step)(ta_l, Xa[a_rows], z_in,
                                              Y[a_rows])
            ta, oa = packed_replica_update(opt, g_a, oa, ta,
                                           xs["as_rep"], as_mask,
                                           flat=spec.flat_opt,
                                           scatter_drop=spec.scatter_drop)
            ring_g = slot_ring_write(ring_g, xs["as_gslot"], g_z, as_mask)
            loss_vec = loss_vec.at[xs["as_epoch"]].add(
                jnp.where(as_mask, loss, 0.0))
            cnt_vec = cnt_vec.at[xs["as_epoch"]].add(
                as_mask.astype(jnp.float32))
            return ta, oa, ring_g, loss_vec, cnt_vec

        ta, oa, ring_g, loss_vec, cnt_vec = jax.lax.cond(
            jnp.any(as_mask), as_phase, lambda args: args,
            (ta, oa, ring_g, loss_vec, cnt_vec))

        # --- in-scan PS aggregation (vfl_ps round barriers) ---
        if spec.has_inscan_agg:
            ta = jax.lax.cond(xs["agg_a"], bm_a, lambda s: s, ta)
            tp = jax.lax.cond(xs["agg_p"], bm_p, lambda s: s, tp)

        return (ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key)

    return tick


def _make_sig_tick(spec: EngineSpec, sig: Tuple[str, ...],
                   has_agg: bool):
    """Cond-free tick body for one phase signature (segmented layout).

    A phase outside `sig` is statically absent from this run, so it is
    simply not traced — no `lax.cond`, hence no branch-unification copy
    of the whole carry per tick (the dominant fixed cost of the packed
    tick at narrow lane widths).  Lanes inside a traced phase may still
    be empty (rep == -1) and are masked elementwise, which fuses into
    the surrounding update instead of copying the carry.  Phase order
    (pb, pf, as), ring semantics and the optimizer masking rules are
    identical to the packed tick; only runs that actually contain
    aggregation ticks (`has_agg`) keep the two in-scan agg conds."""
    p_backward, a_step, publish = _phase_ops(spec)
    bm_a, bm_p = _agg_fns(spec)

    def tick(carry, xs, data, opt, hyper):
        rows_tab, Xa, Xp, Y = data
        clip, sigma = hyper["clip"], hyper["sigma"]
        ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key = carry

        if "pf" in sig and spec.noise:
            key, sub = jax.random.split(key)

        if "pb" in sig:
            pb_mask = xs["pb_rep"] >= 0
            tp_l = gather_replicas(tp, jnp.maximum(xs["pb_rep"], 0))
            xb = Xp[rows_tab[jnp.maximum(xs["pb_bid"], 0)]]
            g_in = slot_ring_read(ring_g, xs["pb_slot"])
            grads_p = jax.vmap(p_backward)(tp_l, xb, g_in)
            tp, op_ = packed_replica_update(opt, grads_p, op_, tp,
                                            xs["pb_rep"], pb_mask,
                                            flat=spec.flat_opt,
                                            scatter_drop=spec.scatter_drop)

        if "pf" in sig:
            pf_mask = xs["pf_rep"] >= 0
            tp_f = gather_replicas(tp, jnp.maximum(xs["pf_rep"], 0))
            xf = Xp[rows_tab[jnp.maximum(xs["pf_bid"], 0)]]
            if spec.noise:
                noise = jax.random.normal(
                    sub, xf.shape[:2] + (ring_e.shape[-1],), jnp.float32)
                z_pub = jax.vmap(
                    lambda th, x, nz: publish(th, x, nz, clip, sigma))(
                        tp_f, xf, noise)
            else:
                z_pub = jax.vmap(
                    lambda th, x: publish(th, x, None, clip, sigma))(tp_f,
                                                                     xf)
            ring_e = slot_ring_write(ring_e, xs["pf_slot"], z_pub, pf_mask)

        if "as" in sig:
            as_mask = xs["as_rep"] >= 0
            ta_l = gather_replicas(ta, jnp.maximum(xs["as_rep"], 0))
            a_rows = rows_tab[jnp.maximum(xs["as_bid"], 0)]
            z_in = slot_ring_read(ring_e, xs["as_eslot"])
            loss, g_a, g_z = jax.vmap(a_step)(ta_l, Xa[a_rows], z_in,
                                              Y[a_rows])
            ta, oa = packed_replica_update(opt, g_a, oa, ta,
                                           xs["as_rep"], as_mask,
                                           flat=spec.flat_opt,
                                           scatter_drop=spec.scatter_drop)
            ring_g = slot_ring_write(ring_g, xs["as_gslot"], g_z, as_mask)
            loss_vec = loss_vec.at[xs["as_epoch"]].add(
                jnp.where(as_mask, loss, 0.0))
            cnt_vec = cnt_vec.at[xs["as_epoch"]].add(
                as_mask.astype(jnp.float32))

        if has_agg:
            ta = jax.lax.cond(xs["agg_a"], bm_a, lambda s: s, ta)
            tp = jax.lax.cond(xs["agg_p"], bm_p, lambda s: s, tp)

        return (ta, oa, tp, op_, ring_e, ring_g, loss_vec, cnt_vec, key)

    return tick


# vmap axes of a point-stacked epoch run: the carry and the hyper
# scalars gain a leading point axis; the tick schedule is broadcast
# (every point replays the SAME pinned timetable — that is what makes a
# structural sweep group one device program); `data` stacks the feature
# blocks/labels per point but shares the schedule's batch-row table.
_STACK_IN_AXES = (0, None, (None, 0, 0, 0), 0)


def _get_segmented_runner(spec: EngineSpec, opt_builder, opt_key,
                          structure: tuple, *, stacked: bool = False):
    """One jitted epoch runner chaining the per-run scans back to back
    with a single donated carry.  `structure` is the epoch's static run
    chain — ((sig, has_agg), ...) — so epochs with the same chain share
    one runner (lane widths and run lengths specialize via jit's shape
    tracing); tick bodies are built per distinct (sig, has_agg) pair.
    The optimizer is (re)built inside the trace from the runtime `hyper`
    learning rate, so the cached runner serves every lr.

    ``stacked=True`` returns the point-stacked variant: the same epoch
    body vmapped over a leading point axis (`_STACK_IN_AXES`), so a
    whole structural sweep group runs as ONE device program — per-point
    params/opt/ring/PRNG carries, per-point data and per-point
    {lr, clip, sigma} vectors, one broadcast tick schedule."""
    cache_key = (spec, opt_key, structure, stacked)
    if opt_key is not None and cache_key in _RUNNER_CACHE:
        return _RUNNER_CACHE[cache_key]
    bodies = {}
    for sig, has_agg in structure:
        if (sig, has_agg) not in bodies:
            bodies[(sig, has_agg)] = _make_sig_tick(spec, sig, has_agg)

    def run(carry, xs_list, data, hyper):
        opt = opt_builder(hyper["lr"])
        for (sig, has_agg), xs in zip(structure, xs_list):
            body = bodies[(sig, has_agg)]
            carry = jax.lax.scan(
                lambda c, x, b=body: (b(c, x, data, opt, hyper), None),
                carry, xs)[0]
        return carry

    fn = jax.vmap(run, in_axes=_STACK_IN_AXES) if stacked else run
    runner = jax.jit(fn, donate_argnums=(0,) if spec.donate else ())
    if opt_key is not None:
        _RUNNER_CACHE[cache_key] = runner
    return runner


def _get_runner(spec: EngineSpec, opt_builder, opt_key, *,
                stacked: bool = False):
    cache_key = (spec, opt_key, stacked)
    if opt_key is not None and cache_key in _RUNNER_CACHE:
        return _RUNNER_CACHE[cache_key]
    mk = _make_packed_tick if spec.pack == "packed" else _make_dense_tick
    tick = mk(spec)

    def run(carry, xs, data, hyper):
        opt = opt_builder(hyper["lr"])
        return jax.lax.scan(lambda c, x: (tick(c, x, data, opt, hyper),
                                          None),
                            carry, xs)[0]

    fn = jax.vmap(run, in_axes=_STACK_IN_AXES) if stacked else run
    runner = jax.jit(fn, donate_argnums=(0,) if spec.donate else ())
    if opt_key is not None:
        _RUNNER_CACHE[cache_key] = runner
    return runner


# ---------------------------------------------------------------------------
# mesh agg hoisting: split epoch scans at in-scan aggregation ticks
# ---------------------------------------------------------------------------
# In-scan aggregation cannot stay inside a mesh-lowered scan: the scan
# carry forces a lane-sharded output on the agg branch, and XLA's
# codegen of the mean under a forced output sharding rounds ~1 ULP off
# the single-device chain (fusion/FMA decisions are layout-dependent).
# Mesh engines therefore split each epoch into scan chunks at the agg
# ticks and run the aggregation BETWEEN chunks through the same
# free-output jitted path as the epoch-boundary agg (bit-exact), laying
# the result back over the lanes with an exact device_put.  A plan is a
# list of ("scan", structure_or_None, xs) and ("agg", do_a, do_p) items
# whose concatenated tick sequence is exactly the unsplit epoch.


def _hoist_chunk_pieces(pieces) -> list:
    """Chunk plan for a chain of segmented run pieces — (sig, has_agg,
    arrays) triples.  Agg flags are stripped from the scanned arrays;
    slices keep their signature so the chained per-slice scans execute
    the identical tick sequence."""
    items: list = []
    cur: list = []

    def flush():
        if cur:
            structure = tuple((sig, False) for sig, _ in cur)
            xs = tuple({k: jnp.asarray(v) for k, v in arrs.items()}
                       for _, arrs in cur)
            items.append(("scan", structure, xs))
            cur.clear()

    for sig, has_agg, raw in pieces:
        arrs = {k: np.asarray(v) for k, v in raw.items()
                if k not in ("agg_a", "agg_p")}
        if not has_agg:
            cur.append((sig, arrs))
            continue
        aa = np.asarray(raw["agg_a"])
        ap = np.asarray(raw["agg_p"])
        lo = 0
        for t in (int(i) for i in np.nonzero(aa | ap)[0]):
            cur.append((sig, {k: v[lo:t + 1] for k, v in arrs.items()}))
            flush()
            items.append(("agg", bool(aa[t]), bool(ap[t])))
            lo = t + 1
        if lo < int(aa.shape[0]):
            cur.append((sig, {k: v[lo:] for k, v in arrs.items()}))
    flush()
    return items


def _hoist_chunk_runs(runs) -> list:
    """Chunk plan for one segmented epoch's run chain."""
    return _hoist_chunk_pieces((r.sig, r.has_agg, r.arrays) for r in runs)


def _hoist_chunk_flat(xs_row: Dict[str, np.ndarray]) -> list:
    """Chunk plan for one packed epoch row.  Padding ticks stay in the
    final chunk — they split the DP PRNG key, so dropping them would
    break bit-parity with the unsplit scan."""
    aa = np.asarray(xs_row.pop("agg_a"))
    ap = np.asarray(xs_row.pop("agg_p"))
    T = int(aa.shape[0])
    items: list = []
    lo = 0
    for t in (int(i) for i in np.nonzero(aa | ap)[0]):
        items.append(("scan", None,
                      {k: jnp.asarray(v[lo:t + 1])
                       for k, v in xs_row.items()}))
        items.append(("agg", bool(aa[t]), bool(ap[t])))
        lo = t + 1
    if lo < T:
        items.append(("scan", None,
                      {k: jnp.asarray(v[lo:]) for k, v in xs_row.items()}))
    return items


# ---------------------------------------------------------------------------
# point-stacking helpers: a structural sweep group's TrainerStates fused
# into one state with a leading point axis (and back)
# ---------------------------------------------------------------------------
def stack_points(states: List["TrainerState"]) -> "TrainerState":
    """Stack per-point `TrainerState`s along a NEW leading point axis.
    All points must sit at the same epoch (they advance in lockstep
    through `run_epoch_stacked`)."""
    epochs = {int(s.epoch) for s in states}
    if len(epochs) != 1:
        raise ValueError(f"cannot stack states at different epochs: "
                         f"{sorted(epochs)}")
    carry = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[TrainerState(*s).carry for s in states])
    return TrainerState(*carry, epoch=epochs.pop())


def point_state(state: "TrainerState", i: int) -> "TrainerState":
    """Slice point `i` out of a point-stacked `TrainerState` — the
    result is an ordinary single-run state, usable with `finish`,
    `params_mean` and `checkpoint.store.save_state`."""
    carry = jax.tree.map(lambda x: x[i], TrainerState(*state).carry)
    return TrainerState(*carry, epoch=state.epoch)


def unstack_points(state: "TrainerState", n_points: int
                   ) -> List["TrainerState"]:
    """Inverse of `stack_points`: the per-point single-run states."""
    return [point_state(state, i) for i in range(n_points)]


# ---------------------------------------------------------------------------
# streaming data path: windowed staging plans (see docs/architecture.md
# §Streaming data path)
# ---------------------------------------------------------------------------
class _Window(NamedTuple):
    """One staging window: a contiguous slice of an epoch's tick stream
    plus the (padded) list of batch ids those ticks touch.  `xs` holds
    the window's tick arrays with batch ids REMAPPED to window-local
    indices, so the jitted tick bodies gather from the small staged
    block instead of the full feature arrays."""
    structure: Optional[tuple]   # segmented run chain; None for flat packs
    xs: Any                      # device tick arrays (tuple of dicts | dict)
    bids: np.ndarray             # (cap,) int64 global batch ids (padded)
    n_bids: int                  # real (unpadded) batch-id count
    plan: Optional[list] = None  # hoisted chunk plan (in-scan agg only)


class WindowedData:
    """`stage_data`'s return value in streaming mode: per-epoch window
    plans plus the host-side feature sources.  `stage(window)` gathers
    the window's rows from the sources and device-puts one bounded block
    — `run_epoch` calls it from a background thread one window ahead of
    execution (double buffering), so at most two windows of features are
    ever staged."""

    def __init__(self, rows: np.ndarray, sources: tuple, plans: list,
                 table, cap: int, window_batches: int):
        self.rows = rows                      # host (n_bids, B) int32
        self.src_a, self.src_p, self.y = sources
        self.plans = plans                    # [seg][k] -> _Window
        self.table = table                    # device (cap, B) int32
        self.cap = cap
        self.window_batches = window_batches
        B = rows.shape[1] if rows.ndim == 2 else 0
        self.stats = {
            "window_batches": int(window_batches),
            "window_cap_bids": int(cap),
            "windows_per_epoch": [len(p) for p in plans],
            "window_rows": int(cap) * int(B),
            "rows_staged": 0, "bytes_staged": 0,
            "peak_staged_bytes": 0, "stage_s": 0.0, "epoch_s": 0.0,
        }
        self._last_bytes = 0

    def n_windows(self, seg: int) -> int:
        return len(self.plans[seg])

    def stage(self, w: _Window) -> tuple:
        t0 = time.perf_counter()
        rows = self.rows[w.bids].reshape(-1)
        Xa = self.src_a[rows]
        Xp = self.src_p[rows]
        yw = self.y[rows]
        nbytes = Xa.size * 4 + Xp.size * 4 + yw.size * 4
        blk = (jnp.asarray(Xa, jnp.float32), jnp.asarray(Xp, jnp.float32),
               jnp.asarray(yw))
        st = self.stats
        st["stage_s"] += time.perf_counter() - t0
        st["rows_staged"] += len(rows)
        st["bytes_staged"] += nbytes
        # double buffering keeps at most this window + the previous one
        st["peak_staged_bytes"] = max(st["peak_staged_bytes"],
                                      nbytes + self._last_bytes)
        self._last_bytes = nbytes
        return blk


def _fixed_window_len(tick_bids: List[np.ndarray], cap: int
                      ) -> Tuple[int, int]:
    """Largest uniform tick-window length whose every aligned window
    touches at most `cap` distinct batch ids.  Uniform length keeps the
    steady-state windows shape-identical (one jit specialization); `cap`
    is raised to the densest single tick when necessary, so the search
    always terminates.  Returns (window_len, effective_cap)."""
    sizes = [len(b) for b in tick_bids]
    cap = max(int(cap), max(sizes) if sizes else 1, 1)
    T = len(tick_bids)
    T_w = max(T, 1)
    while True:
        worst = 0
        for lo in range(0, T, T_w):
            cat = np.concatenate(tick_bids[lo:lo + T_w])
            worst = max(worst, len(np.unique(cat)))
        if worst <= cap:
            return T_w, cap
        T_w = max(1, min(T_w - 1, (T_w * cap) // worst))


def _remap_bids(arrs: Dict[str, np.ndarray], bids: np.ndarray,
                n_total: int) -> Dict[str, np.ndarray]:
    """Rewrite `*_bid` tick arrays from global batch ids to window-local
    indices (position within `bids`); -1 (idle lane) is preserved."""
    local = np.full(max(n_total, 1), -1, np.int32)
    local[bids] = np.arange(len(bids), dtype=np.int32)
    out = {}
    for k, v in arrs.items():
        if k.endswith("_bid"):
            v = np.where(v >= 0, local[np.maximum(v, 0)],
                         -1).astype(np.int32)
        out[k] = v
    return out


class CompiledReplayEngine:
    """Executes a `CompiledSchedule` as jitted per-epoch scan segments.

    Implements the `ReplayEngine` protocol (`core.engines.ReplayEngine`):
    ``stage_data`` → ``init_state`` → ``run_epoch``* → ``finish``.  The
    constructor's `clip`/`sigma`/`lr` only set the engine's *default*
    `hyper` values — they are runtime scalars of the jitted runners, so
    one engine instance (and one XLA program) serves every lr/dp_mu of a
    sweep; only the DP structure (on/off, noise on/off) is compiled in.

    ``n_devices > 1`` (or an explicit ``mesh=``) lays the replica axis —
    and the point axis of stacked sweeps — over a 1-D ``("replica",)``
    mesh: the schedule is re-lowered through `schedule.device_lower`
    (slab-balanced lane permutation + masked padding lanes when the
    replica count doesn't divide), the carry's param/opt stacks get a
    `NamedSharding` over their lane axis, and the SAME cached jitted
    runners execute the partitioned program — GSPMD inserts the only
    cross-device collectives (the aggregation psum at agg ticks, plus
    ring exchange), bit-for-bit equal to the single-device path (see
    `core.mesh_replay` and tests/test_mesh_replay.py)."""

    def __init__(self, schedule: CompiledSchedule, *, opt=None,
                 task: str, resnet: bool = False,
                 clip: float = math.inf, sigma: float = 0.0,
                 lr: float = 1e-3, use_pallas: Optional[bool] = None,
                 seed: int = 0, flat_opt: Optional[bool] = None,
                 scatter_drop: bool = False, n_devices: int = 1,
                 mesh=None):
        enable_persistent_cache()
        if mesh is not None or int(n_devices) > 1:
            self.mesh = mesh if mesh is not None \
                else mesh_replay.make_replay_mesh(n_devices)
            self.n_devices = int(self.mesh.devices.size)
            schedule = device_lower(schedule, self.n_devices)
        else:
            self.mesh = None
            self.n_devices = 1
        self.schedule = schedule
        if opt is not None:
            self.opt = opt
            opt_builder = lambda _lr: opt        # custom opt: lr fixed
            opt_key = None
        else:
            self.opt = adam(lr)
            opt_builder = adam
            opt_key = ("adam",)
        dp = sigma > 0.0 or math.isfinite(clip)
        self.hyper = {"lr": jnp.float32(lr), "clip": jnp.float32(clip),
                      "sigma": jnp.float32(sigma)}
        backend = jax.default_backend()
        if use_pallas is None:
            use_pallas = backend == "tpu"
        if flat_opt is None:
            # fused flat optimizer update: a handful of elementwise ops
            # over one contiguous buffer instead of ~2L per-leaf
            # dispatches.  Measured ~2x SLOWER on XLA-CPU (the per-tick
            # gather/concat/split copies dominate there, same pathology
            # as the parked flat carry layout), so it defaults on only
            # off-CPU; REPRO benchmarks A/B it via the explicit knob.
            flat_opt = schedule.pack == "segmented" and backend != "cpu"
        perm_a = perm_p = None
        if schedule.slab_a is not None and not schedule.slab_a.is_identity:
            perm_a = schedule.slab_a.lane_of
        if schedule.slab_p is not None and not schedule.slab_p.is_identity:
            perm_p = schedule.slab_p.lane_of
        self.spec = EngineSpec(
            n_rep_a=schedule.n_rep_a, n_rep_p=schedule.n_rep_p, task=task,
            resnet=resnet, dp=dp, noise=sigma > 0.0,
            has_inscan_agg=schedule.has_inscan_agg, use_pallas=use_pallas,
            donate=backend != "cpu", pack=schedule.pack,
            flat_opt=bool(flat_opt), scatter_drop=scatter_drop,
            agg_perm_a=perm_a, agg_perm_p=perm_p)
        # schedules with in-scan aggregation hoist it out of the scans
        # (see the chunk-plan helpers above) on EVERY device count: the
        # single-device reference and a mesh run must share the same
        # standalone agg kernels for bit-parity, so the tick bodies
        # trace agg-free everywhere and the agg runs between scan chunks
        self._hoist = bool(schedule.has_inscan_agg)
        if self._hoist:
            self.spec = _dc_replace(self.spec, has_inscan_agg=False)
        self._opt_builder, self._opt_key = opt_builder, opt_key
        if schedule.pack == "segmented":
            # one runner per epoch run-chain (shared across epochs with
            # the same chain) + device-resident per-run xs
            self._structures = [
                tuple((r.sig, r.has_agg) for r in seg.runs)
                for seg in schedule.segments]
            self._runners = [
                _get_segmented_runner(self.spec, opt_builder, opt_key,
                                      structure)
                if structure else None
                for structure in self._structures]
            self._seg_xs = [
                tuple({k: jnp.asarray(v) for k, v in r.arrays.items()}
                      for r in seg.runs)
                for seg in schedule.segments]
        else:
            self._runner = _get_runner(self.spec, opt_builder, opt_key)
            self._xs = {k: jnp.asarray(v)
                        for k, v in schedule.padded().items()}
        bm_a, bm_p = _agg_fns(self.spec,
                               on_mesh=self.mesh is not None)
        agg = lambda ta, tp: (bm_a(ta), bm_p(tp))
        if self.mesh is not None:
            # pin canonical lane sharding on the boundary-agg INPUTS so
            # the agg always compiles against the layout the parity
            # proof covers (jit reshards drifted inputs for free).  The
            # output sharding stays free on purpose: forcing a
            # lane-sharded output makes the partitioner compute each
            # device's slab of the broadcast mean from per-device
            # partial sums + cross-device reduce, whose association is
            # ~1 ULP off the single-device chain.  `_place_state` lays
            # the free (replicated) result back over the lanes at the
            # epoch boundary.
            lane = mesh_replay.lane_sharding(self.mesh)
            self._agg_both = jax.jit(agg, in_shardings=(lane, lane))
            if self._hoist:
                # one-party variants for the hoisted agg ticks (a vfl_ps
                # round may barrier only one party); same pin discipline
                self._agg_a = jax.jit(bm_a, in_shardings=lane)
                self._agg_p = jax.jit(bm_p, in_shardings=lane)
        else:
            self._agg_both = jax.jit(agg)
            if self._hoist:
                self._agg_a = jax.jit(bm_a)
                self._agg_p = jax.jit(bm_p)
        # live-subset boundary aggs (faulty worlds): built lazily per
        # distinct (live set, stacked) pair — healthy runs never pay
        self._live_agg_cache: Dict[tuple, Any] = {}
        self._hoist_plans = None
        if self._hoist:
            if schedule.pack == "segmented":
                self._hoist_plans = [_hoist_chunk_runs(seg.runs)
                                     for seg in schedule.segments]
            else:
                padded = schedule.padded()
                self._hoist_plans = [
                    _hoist_chunk_flat({k: np.asarray(v[i])
                                       for k, v in padded.items()})
                    for i in range(len(schedule.segments))]
        # the point-stacked runners (the same epoch bodies vmapped over a
        # leading point axis) are built lazily on the first stacked call,
        # so single-run users never pay their traces
        self._stacked_ready = False
        self._seed = seed
        # streaming window plans, keyed by window_batches (built lazily
        # on the first windowed stage_data; resident users never pay)
        self._stream_plans: Dict[int, tuple] = {}

    # -- ReplayEngine protocol: bookkeeping resolved at compile time -----
    @property
    def staleness(self) -> List[int]:
        return self.schedule.staleness

    @property
    def n_updates(self) -> int:
        return self.schedule.n_updates

    @property
    def versions_p(self) -> List[int]:
        return list(self.schedule.versions_p)

    @property
    def n_epochs(self) -> int:
        return self.schedule.n_epochs

    # -- staging ---------------------------------------------------------
    def stage_data(self, Xa, Xp, y, *,
                   window_batches: Optional[int] = None):
        """Resident mode (plain arrays, no `window_batches`): device-put
        the full feature blocks and the batch-row table once; every tick
        gathers its minibatch on device (no per-step host staging, no
        per-step transfers).

        Streaming mode (a `data.shards` feature source for either party,
        or an explicit `window_batches`): returns a `WindowedData` plan
        instead — `run_epoch` then scans the epoch in staging windows of
        at most ~`window_batches` batches, double-buffering the
        host-gather + device-put of window k+1 behind the execution of
        window k.  Windows partition the exact resident tick stream
        (same ticks, same order, same per-tick PRNG splits), so streamed
        results are bit-for-bit equal to the resident path."""
        streaming = (window_batches is not None
                     or is_feature_source(Xa) or is_feature_source(Xp))
        if not streaming:
            data = (jnp.asarray(self.schedule.rows),
                    jnp.asarray(Xa, jnp.float32),
                    jnp.asarray(Xp, jnp.float32), jnp.asarray(y))
            if self.mesh is not None:
                # every lane reads arbitrary rows -> features replicate
                data = mesh_replay.put_replicated(self.mesh, data)
            return data
        wb = int(window_batches) if window_batches else 32
        plans, table, cap = self._stream_plan(wb)
        rows = np.asarray(self.schedule.rows)
        y = np.asarray(y)
        return WindowedData(rows, (Xa, Xp, y), plans, table, cap, wb)

    # -- streaming window plans -----------------------------------------
    def _stream_plan(self, window_batches: int) -> tuple:
        """(plans, table, cap) for a window budget: per-epoch lists of
        `_Window`s partitioning that epoch's tick stream, the shared
        window-local batch-row table, and the padded per-window batch-id
        capacity (shared across windows so steady-state windows are
        shape-identical and reuse one jit specialization)."""
        plan = self._stream_plans.get(window_batches)
        if plan is not None:
            return plan
        s = self.schedule
        if s.pack == "segmented":
            raw = [self._plan_segmented(seg, window_batches)
                   for seg in s.segments]
        else:
            padded = s.padded()
            raw = [self._plan_flat({k: v[i] for k, v in padded.items()},
                                   window_batches)
                   for i in range(len(s.segments))]
        cap = max((w["n_bids"] for ws in raw for w in ws), default=1)
        cap = max(cap, 1)
        n_total = int(s.rows.shape[0])
        plans = [[self._finalize_window(w, cap, n_total) for w in ws]
                 for ws in raw]
        table = jnp.arange(cap * s.batch_rows,
                           dtype=jnp.int32).reshape(cap, s.batch_rows)
        plan = (plans, table, cap)
        self._stream_plans[window_batches] = plan
        return plan

    @staticmethod
    def _tick_bid_sets(arr_list: List[np.ndarray], T: int
                       ) -> List[np.ndarray]:
        out = []
        for t in range(T):
            if arr_list:
                b = np.concatenate([np.asarray(a[t]).ravel()
                                    for a in arr_list])
                out.append(np.unique(b[b >= 0]))
            else:
                out.append(np.empty(0, np.int64))
        return out

    def _plan_segmented(self, seg, window_batches: int) -> List[dict]:
        """Partition one epoch's run chain into tick windows.  A window
        boundary may fall inside a run — the run is sliced along its
        tick axis (slices keep the run's signature/has_agg, so the
        chained per-slice scans execute the identical tick sequence)."""
        tick_bids: List[np.ndarray] = []
        owner: List[int] = []
        starts: List[int] = []
        t0 = 0
        for ri, r in enumerate(seg.runs):
            starts.append(t0)
            bid_arrs = [np.asarray(r.arrays[f"{ph}_bid"]) for ph in r.sig]
            tick_bids.extend(self._tick_bid_sets(bid_arrs, r.n_ticks))
            owner.extend([ri] * r.n_ticks)
            t0 += r.n_ticks
        T = len(tick_bids)
        if T == 0:
            return []
        T_w, _ = _fixed_window_len(tick_bids, window_batches)
        windows = []
        for lo in range(0, T, T_w):
            hi = min(T, lo + T_w)
            bids = np.unique(np.concatenate(tick_bids[lo:hi]))
            pieces = []
            t = lo
            while t < hi:
                ri = owner[t]
                r = seg.runs[ri]
                a = t - starts[ri]
                b = min(r.n_ticks, a + (hi - t))
                arrs = {k: np.asarray(v)[a:b]
                        for k, v in r.arrays.items()}
                pieces.append((r.sig, r.has_agg, arrs))
                t += b - a
            windows.append({"bids": bids, "pieces": pieces,
                            "n_bids": len(bids)})
        return windows

    def _plan_flat(self, xs_host: Dict[str, np.ndarray],
                   window_batches: int) -> List[dict]:
        """Partition one epoch's padded tick arrays (packed/dense packs)
        into tick windows.  The padded tick count is preserved exactly —
        padding ticks also split the DP PRNG key, so dropping them would
        break bit-parity with the resident scan."""
        bid_keys = [k for k in xs_host if k.endswith("_bid")]
        T = int(next(iter(xs_host.values())).shape[0])
        tick_bids = self._tick_bid_sets([xs_host[k] for k in bid_keys], T)
        if T == 0:
            return []
        T_w, _ = _fixed_window_len(tick_bids, window_batches)
        windows = []
        for lo in range(0, T, T_w):
            hi = min(T, lo + T_w)
            bids = np.unique(np.concatenate(tick_bids[lo:hi]))
            arrs = {k: v[lo:hi] for k, v in xs_host.items()}
            windows.append({"bids": bids, "pieces": arrs,
                            "n_bids": len(bids)})
        return windows

    def _finalize_window(self, w: dict, cap: int, n_total: int) -> _Window:
        bids = np.asarray(w["bids"], np.int64)
        n = len(bids)
        padded = np.full(cap, bids[-1] if n else 0, np.int64)
        padded[:n] = bids
        pieces = w["pieces"]
        plan = None
        if isinstance(pieces, dict):              # packed/dense
            remapped = _remap_bids(pieces, bids, n_total)
            if self._hoist:
                plan = _hoist_chunk_flat(
                    {k: np.asarray(v) for k, v in remapped.items()})
                xs, structure = None, None
            else:
                xs = {k: jnp.asarray(v) for k, v in remapped.items()}
                structure = None
        else:                                     # segmented run slices
            remapped = [(sig, has_agg, _remap_bids(arrs, bids, n_total))
                        for sig, has_agg, arrs in pieces]
            if self._hoist:
                plan = _hoist_chunk_pieces(remapped)
                xs, structure = None, None
            else:
                structure = tuple((sig, has_agg)
                                  for sig, has_agg, _ in remapped)
                xs = tuple({k: jnp.asarray(v) for k, v in arrs.items()}
                           for _, _, arrs in remapped)
        return _Window(structure=structure, xs=xs, bids=padded, n_bids=n,
                       plan=plan)

    @staticmethod
    def _lane_lists(reps: List, plan) -> List:
        """Arrange per-replica leaves into device-lowered lane order.
        Padding lanes carry a copy of replica 0's values — inert, since
        no `*_rep` work row ever names them (a lane-ordered list of the
        full lane length is passed through unchanged)."""
        if plan is None or plan.is_identity or len(reps) == plan.n_lanes:
            return list(reps)
        return [reps[r] if r >= 0 else reps[0] for r in plan.rep_of]

    def _place_state(self, state: TrainerState) -> TrainerState:
        """Lay the carry over the replica mesh (no-op off-mesh)."""
        if self.mesh is None:
            return state
        carry = mesh_replay.shard_carry(self.mesh,
                                        TrainerState(*state).carry)
        return TrainerState(*carry, epoch=int(state.epoch),
                            window=int(getattr(state, "window", 0)))

    def _build_state(self, theta_a_reps: List, opt_a_reps: List,
                     theta_p_reps: List, opt_p_reps: List, d_emb: int,
                     seed: Optional[int]) -> TrainerState:
        s = self.schedule
        B = s.batch_rows
        key0 = jax.random.fold_in(
            jax.random.PRNGKey(self._seed if seed is None else seed), 0x5f)
        return TrainerState(
            stack_states(self._lane_lists(theta_a_reps, s.slab_a)),
            stack_states(self._lane_lists(opt_a_reps, s.slab_a)),
            stack_states(self._lane_lists(theta_p_reps, s.slab_p)),
            stack_states(self._lane_lists(opt_p_reps, s.slab_p)),
            slot_ring_init(s.emb_slots, (B, d_emb)),
            slot_ring_init(s.grad_slots, (B, d_emb)),
            jnp.zeros((s.n_epochs,), jnp.float32),
            jnp.zeros((s.n_epochs,), jnp.float32),
            key0, epoch=0)

    def init_state(self, theta_a_reps: List, opt_a_reps: List,
                   theta_p_reps: List, opt_p_reps: List, d_emb: int,
                   *, seed: Optional[int] = None) -> TrainerState:
        """Fresh `TrainerState` at epoch 0.  `seed` (default: the
        engine's construction seed) keys the device DP noise stream — a
        cached engine serves many runs, each seeding its own state.  The
        per-replica lists are in canonical replica order; on a mesh
        engine they are padded/permuted into lane order and the carry is
        laid over the devices."""
        return self._place_state(self._build_state(
            theta_a_reps, opt_a_reps, theta_p_reps, opt_p_reps, d_emb,
            seed))

    def load_state(self, payload) -> TrainerState:
        """Rebuild a `TrainerState` from a `checkpoint.store.restore_state`
        payload (the state saved with `save_state`).  Accepts both the
        10-field pre-streaming layout (no `window`; mid-epoch resume did
        not exist) and the current 11-field one.  The payload's stacks
        may be canonical (`export_state`, device-count independent) or
        this engine's own lane layout — both adopt correctly, so a run
        saved on N devices resumes on M."""
        fields = list(payload)
        window = int(fields[10]) if len(fields) > 10 else 0
        st = TrainerState(*fields[:9], epoch=int(fields[9]),
                          window=window)
        return self._adopt_state(st)

    def _adopt_state(self, st: TrainerState) -> TrainerState:
        """Canonical (or already-lane-ordered) state -> this engine's
        lane layout and device placement."""
        s = self.schedule

        def pad(stack, plan):
            if plan is None or plan.is_identity:
                return stack

            def leaf(x):
                x = jnp.asarray(x)
                if int(x.shape[0]) == plan.n_lanes:
                    return x                      # already lane-ordered
                idx = jnp.maximum(jnp.asarray(plan.rep_of), 0)
                return x[idx]                     # pad lanes <- replica 0
            return jax.tree.map(leaf, stack)

        st = TrainerState(
            pad(st.theta_a, s.slab_a), pad(st.opt_a, s.slab_a),
            pad(st.theta_p, s.slab_p), pad(st.opt_p, s.slab_p),
            *tuple(st)[4:9], epoch=int(st.epoch),
            window=int(getattr(st, "window", 0)))
        return self._place_state(st)

    def export_state(self, state: TrainerState) -> TrainerState:
        """Device-count-independent view of `state`: real replicas in
        canonical order, padding lanes stripped.  This is what
        checkpoints should hold — `load_state` on an engine with ANY
        device count adopts it — and it is the identity off-mesh and on
        divisible (identity-plan) mesh layouts."""
        s = self.schedule
        if s.slab_a is None and s.slab_p is None:
            return state

        def sel(stack, plan):
            if plan is None or plan.is_identity:
                return stack
            idx = jnp.asarray(plan.lane_of)
            return jax.tree.map(lambda x: jnp.asarray(x)[idx], stack)

        return TrainerState(
            sel(state.theta_a, s.slab_a), sel(state.opt_a, s.slab_a),
            sel(state.theta_p, s.slab_p), sel(state.opt_p, s.slab_p),
            *tuple(state)[4:9], epoch=int(state.epoch),
            window=int(getattr(state, "window", 0)))

    # -- execution -------------------------------------------------------
    def _epoch_agg(self, seg: int, *, stacked: bool = False):
        """The boundary-aggregation callable for segment `seg` (None =
        this segment has no Eq. 5 sync mark): the healthy `_agg_both`
        for all-live boundaries — byte-identical to the pre-fault path —
        or a cached live-subset variant when crashed replicas must sit
        the pull out (schedule.epoch_live, from the fault lowering)."""
        if not self.schedule.segments[seg].epoch_agg:
            return None
        el = self.schedule.epoch_live
        live = el[seg] if el and seg < len(el) else None
        if live is None:
            return self._agg_both_stacked if stacked else self._agg_both
        return self._live_agg_fn(live, stacked=stacked)

    def _live_agg_fn(self, live: tuple, *, stacked: bool = False):
        """Build (and cache) the jitted subset boundary agg for one
        `(live_a, live_p)` snapshot.  Live sets arrive in CANONICAL
        replica indices; they are translated to lanes through the slab
        plans here.  A side whose subset is the full replica set routes
        through the healthy agg fn; an empty side (whole party down) is
        skipped — nothing to pull."""
        key = (live, bool(stacked))
        fn = self._live_agg_cache.get(key)
        if fn is not None:
            return fn
        s = self.schedule
        bm_a, bm_p = _agg_fns(self.spec, on_mesh=self.mesh is not None)

        def side(reps, slab, n_lanes, bm):
            n_real = slab.n_real if slab is not None else n_lanes
            if len(reps) == n_real:
                return bm
            if not reps:
                return None
            if slab is not None and not slab.is_identity:
                perm = tuple(slab.lane_of[r] for r in reps)
            else:
                perm = tuple(reps)
            mask = np.zeros((n_lanes,), bool)
            mask[list(perm)] = True
            return lambda st: _live_broadcast_mean(st, perm, mask)

        fa = side(live[0], s.slab_a, s.n_rep_a, bm_a)
        fp = side(live[1], s.slab_p, s.n_rep_p, bm_p)

        def agg(ta, tp):
            if fa is not None:
                ta = fa(ta)
            if fp is not None:
                tp = fp(tp)
            return ta, tp
        if stacked:
            agg = jax.vmap(agg)
        if self.mesh is not None:
            # same pin discipline as `_agg_both`: canonical lane sharding
            # on the inputs, output left free; the caller's
            # `_place_state` / `shard_stacked_carry` re-pins at the
            # epoch boundary
            lane = mesh_replay.lane_sharding(self.mesh)
            jfn = jax.jit(agg, in_shardings=(lane, lane))
        else:
            jfn = jax.jit(agg)
        self._live_agg_cache[key] = jfn
        return jfn

    def run_epoch(self, state: TrainerState, seg: int, data,
                  hyper: Optional[Dict] = None, *,
                  max_windows: Optional[int] = None) -> TrainerState:
        """Execute epoch `seg` and return the advanced state.  `hyper`
        overrides the runtime scalars {lr, clip, sigma} for this call
        (default: the engine's construction values).

        With a `WindowedData` plan (streaming `stage_data`), the epoch
        runs window by window with double-buffered staging; execution
        resumes from `state.window` and `max_windows` (tests /
        checkpointing) stops after that many windows, returning a state
        parked mid-epoch (`epoch` unchanged, `window` advanced)."""
        if hyper is None:
            hyper = self.hyper
        else:
            hyper = {k: jnp.float32(hyper[k]) for k in ("lr", "clip",
                                                        "sigma")}
        if isinstance(data, WindowedData):
            return self._run_epoch_windowed(state, seg, data, hyper,
                                            max_windows)
        if int(getattr(state, "window", 0)):
            raise ValueError("state is parked mid-epoch (window "
                             f"{int(state.window)}); resuming requires "
                             "the streaming data path")
        carry = TrainerState(*state).carry
        if self._hoist_plans is not None:
            carry = self._run_hoisted(carry, self._hoist_plans[seg],
                                      data, hyper)
        elif self.schedule.pack == "segmented":
            if self.schedule.segments[seg].runs:
                carry = self._runners[seg](carry, self._seg_xs[seg], data,
                                           hyper)
        else:
            xs = {k: v[seg] for k, v in self._xs.items()}
            carry = self._runner(carry, xs, data, hyper)
        agg = self._epoch_agg(seg)
        if agg is not None:
            ta, oa, tp, op_, *rest = carry
            ta, tp = agg(ta, tp)
            carry = (ta, oa, tp, op_, *rest)
        # re-pin canonical shardings at the epoch boundary (no-op copy
        # when nothing drifted) so every epoch's scan compiles against
        # the same layout
        return self._place_state(TrainerState(*carry, epoch=seg + 1))

    def _run_hoisted(self, carry, plan, data, hyper, *,
                     stacked: bool = False):
        """Execute one epoch's hoisted chunk plan: jitted scan chunks
        with the in-scan aggregations applied between them through the
        exact free-output agg path, each result laid back over the lanes
        (or the point axis, for stacked groups) by a device_put."""
        lane = (mesh_replay.lane_sharding(self.mesh)
                if self.mesh is not None else None)
        for item in plan:
            if item[0] == "agg":
                _, do_a, do_p = item
                ta, oa, tp, op_, *rest = carry
                if do_a:
                    fn = self._agg_a_stacked if stacked else self._agg_a
                    ta = fn(ta)
                    if lane is not None:
                        ta = jax.device_put(ta, lane)
                if do_p:
                    fn = self._agg_p_stacked if stacked else self._agg_p
                    tp = fn(tp)
                    if lane is not None:
                        tp = jax.device_put(tp, lane)
                carry = (ta, oa, tp, op_, *rest)
            else:
                _, structure, xs = item
                if structure is None:
                    runner = (self._stacked_runner if stacked
                              else self._runner)
                else:
                    runner = _get_segmented_runner(
                        self.spec, self._opt_builder, self._opt_key,
                        structure, stacked=stacked)
                carry = runner(carry, xs, data, hyper)
        return carry

    def _run_epoch_windowed(self, state: TrainerState, seg: int,
                            data: WindowedData, hyper: Dict,
                            max_windows: Optional[int]) -> TrainerState:
        wins = data.plans[seg]
        w0 = int(getattr(state, "window", 0))
        end = len(wins)
        if max_windows is not None:
            end = min(end, w0 + max(1, int(max_windows)))
        carry = TrainerState(*state).carry
        t0 = time.perf_counter()
        pool = ThreadPoolExecutor(max_workers=1)

        def take(fut, k):
            # surface a background staging failure (host gather,
            # device_put) as THIS epoch's exception, chained to the
            # original — never a hang or an opaque re-raise
            try:
                return fut.result()
            except StagingError:
                raise
            except BaseException as e:
                raise StagingError(
                    f"background staging of window {k} (epoch {seg}) "
                    f"failed: {e!r}") from e
        try:
            fut = pool.submit(data.stage, wins[w0]) if w0 < end else None
            for k in range(w0, end):
                blk = take(fut, k)
                if k + 1 < end:
                    # prefetch: host-gather + device-put window k+1 while
                    # window k's (async-dispatched) scan executes
                    fut = pool.submit(data.stage, wins[k + 1])
                w = wins[k]
                wdata = (data.table, *blk)
                if w.plan is not None:
                    carry = self._run_hoisted(carry, w.plan, wdata, hyper)
                elif self.schedule.pack == "segmented":
                    if w.structure:
                        runner = _get_segmented_runner(
                            self.spec, self._opt_builder, self._opt_key,
                            w.structure)
                        carry = runner(carry, w.xs, wdata, hyper)
                else:
                    carry = self._runner(carry, w.xs, wdata, hyper)
        finally:
            # never block the failing epoch on a hung or still-running
            # prefetch thread; cancel what has not started and let the
            # daemonized worker drain on its own
            pool.shutdown(wait=False, cancel_futures=True)
        data.stats["epoch_s"] += time.perf_counter() - t0
        if end < len(wins):
            return self._place_state(
                TrainerState(*carry, epoch=int(state.epoch), window=end))
        agg = self._epoch_agg(seg)
        if agg is not None:
            ta, oa, tp, op_, *rest = carry
            ta, tp = agg(ta, tp)
            carry = (ta, oa, tp, op_, *rest)
        return self._place_state(
            TrainerState(*carry, epoch=seg + 1, window=0))

    def run_segment(self, state, seg: int, data: tuple) -> TrainerState:
        """Back-compat alias of `run_epoch` (pre-Session name)."""
        return self.run_epoch(state, seg, data)

    # -- point-stacked execution (whole sweep groups as one program) -----
    def _ensure_stacked_runners(self) -> None:
        if self._stacked_ready:
            return
        if self.schedule.pack == "segmented":
            self._stacked_runners = [
                _get_segmented_runner(self.spec, self._opt_builder,
                                      self._opt_key, structure,
                                      stacked=True)
                if structure else None
                for structure in self._structures]
        else:
            self._stacked_runner = _get_runner(
                self.spec, self._opt_builder, self._opt_key, stacked=True)
        bm_a, bm_p = _agg_fns(self.spec,
                               on_mesh=self.mesh is not None)
        agg = jax.vmap(lambda ta, tp: (bm_a(ta), bm_p(tp)))
        if self.mesh is not None:
            # same pin discipline as `_agg_both`, on the point axis: pin
            # the inputs, leave the output free (a forced output
            # sharding flips layout-dependent fusion/FMA rounding);
            # `shard_stacked_carry` re-pins at the epoch boundary
            lane = mesh_replay.lane_sharding(self.mesh)
            self._agg_both_stacked = jax.jit(
                agg, in_shardings=(lane, lane))
            if self._hoist:
                self._agg_a_stacked = jax.jit(jax.vmap(bm_a),
                                              in_shardings=lane)
                self._agg_p_stacked = jax.jit(jax.vmap(bm_p),
                                              in_shardings=lane)
        else:
            self._agg_both_stacked = jax.jit(agg)
            if self._hoist:
                self._agg_a_stacked = jax.jit(jax.vmap(bm_a))
                self._agg_p_stacked = jax.jit(jax.vmap(bm_p))
        self._stacked_ready = True

    def stage_data_stacked(self, points: List[tuple]) -> tuple:
        """Device-put a sweep group's feature blocks with a leading point
        axis.  `points` is a list of per-point ``(Xa, Xp, y)``; shapes
        must match across points (they do within a structural group —
        n_samples/d_a/d_p are part of the key).  The schedule's batch-row
        table is shared: every point replays the same pinned timetable."""
        if any(is_feature_source(xa) or is_feature_source(xp)
               for xa, xp, _ in points):
            raise TypeError("point stacking requires resident feature "
                            "arrays; streaming sources run sequentially")
        self._check_point_count(len(points))
        data = (jnp.asarray(self.schedule.rows),
                jnp.stack([jnp.asarray(xa, jnp.float32)
                           for xa, _, _ in points]),
                jnp.stack([jnp.asarray(xp, jnp.float32)
                           for _, xp, _ in points]),
                jnp.stack([jnp.asarray(y) for _, _, y in points]))
        if self.mesh is not None:
            data = mesh_replay.shard_stacked_data(self.mesh, data)
        return data

    def _check_point_count(self, n_points: int) -> None:
        if self.mesh is not None and n_points % self.n_devices:
            raise ValueError(
                f"a mesh-stacked group must hold a multiple of "
                f"n_devices={self.n_devices} points, got {n_points}; pad "
                f"the group (api.sweep repeats the last point)")

    def init_state_stacked(self, points: List[tuple], d_emb: int, *,
                           seeds: List[int]) -> TrainerState:
        """Fresh point-stacked `TrainerState`: per-point model/opt
        replicas stacked along a new leading axis, one DP PRNG key per
        point (keyed exactly like the per-point `init_state`, so a
        stacked DP run draws the same noise its sequential run would).
        `points` is a list of per-point
        ``(theta_a_reps, opt_a_reps, theta_p_reps, opt_p_reps)``.

        On a mesh engine the POINT axis (not the replica axis) is laid
        over the devices — stacked points are embarrassingly parallel,
        so a sharded group runs with zero steady-state collectives."""
        self._check_point_count(len(points))
        states = [self._build_state(ta, oa, tp, op_, d_emb, s)
                  for (ta, oa, tp, op_), s in zip(points, seeds)]
        st = stack_points(states)
        if self.mesh is not None:
            carry = mesh_replay.shard_stacked_carry(
                self.mesh, TrainerState(*st).carry)
            st = TrainerState(*carry, epoch=int(st.epoch))
        return st

    def run_epoch_stacked(self, state: TrainerState, seg: int,
                          data: tuple, hyper: Dict) -> TrainerState:
        """Execute epoch `seg` for EVERY point of a stacked state in one
        device program.  `hyper` holds per-point vectors — {lr, clip,
        sigma} each of shape (n_points,) — so a group may mix learning
        rates and DP budgets (DP on/off is structure and uniform across
        the group)."""
        hyper = {k: jnp.asarray(hyper[k], jnp.float32).reshape(-1)
                 for k in ("lr", "clip", "sigma")}
        self._ensure_stacked_runners()
        carry = TrainerState(*state).carry
        if self._hoist_plans is not None:
            carry = self._run_hoisted(carry, self._hoist_plans[seg],
                                      data, hyper, stacked=True)
        elif self.schedule.pack == "segmented":
            if self.schedule.segments[seg].runs:
                carry = self._stacked_runners[seg](
                    carry, self._seg_xs[seg], data, hyper)
        else:
            xs = {k: v[seg] for k, v in self._xs.items()}
            carry = self._stacked_runner(carry, xs, data, hyper)
        agg = self._epoch_agg(seg, stacked=True)
        if agg is not None:
            ta, oa, tp, op_, *rest = carry
            ta, tp = agg(ta, tp)
            carry = (ta, oa, tp, op_, *rest)
        if self.mesh is not None:
            carry = mesh_replay.shard_stacked_carry(self.mesh, carry)
        return TrainerState(*carry, epoch=seg + 1)

    def point_state(self, state: TrainerState, i: int) -> TrainerState:
        """Point `i`'s ordinary single-run state (see `point_state`)."""
        return point_state(state, i)

    def unstack_points(self, state: TrainerState, n_points: int
                       ) -> List[TrainerState]:
        """All per-point states of a stacked state (for `finish` /
        checkpointing)."""
        return unstack_points(state, n_points)

    def params_mean(self, state) -> tuple:
        """(theta_a, theta_p) averaged across replicas — for evaluation.
        On device-lowered layouts the mean runs over the real lanes in
        canonical replica order (padding lanes excluded).  In a faulty
        world the mean covers the END-OF-LOG survivors only
        (schedule.final_live, matching the event engine): a crashed
        replica's frozen params are not part of the served model.  An
        empty live side (whole party failed-stop) degenerates to the
        full mean."""
        ta, _, tp, *_ = tuple(state)
        s = self.schedule
        pa, pp = self.spec.agg_perm_a, self.spec.agg_perm_p
        fl = s.final_live
        if fl is not None:
            def live_perm(reps, slab, n_lanes, default):
                n_real = slab.n_real if slab is not None else n_lanes
                if not reps or len(reps) == n_real:
                    return default
                if slab is not None and not slab.is_identity:
                    return tuple(slab.lane_of[r] for r in reps)
                return tuple(reps)
            pa = live_perm(fl[0], s.slab_a, s.n_rep_a, pa)
            pp = live_perm(fl[1], s.slab_p, s.n_rep_p, pp)
        return (replica_mean(ta, pa), replica_mean(tp, pp))

    def finish(self, state):
        """Unstack params/opt back to per-replica lists (canonical
        replica order — padding lanes dropped) and pull the
        device-accumulated per-epoch mean losses (ONE host sync)."""
        ta, oa, tp, op_, _, _, loss_vec, cnt_vec, *_ = tuple(state)
        s = self.schedule

        def unstack(stack, n_lanes, plan):
            lst = unstack_states(stack, n_lanes)
            if plan is not None and not plan.is_identity:
                lst = [lst[l] for l in plan.lane_of]
            return lst

        losses = np.asarray(loss_vec) / np.maximum(np.asarray(cnt_vec), 1.0)
        return (unstack(ta, s.n_rep_a, s.slab_a),
                unstack(oa, s.n_rep_a, s.slab_a),
                unstack(tp, s.n_rep_p, s.slab_p),
                unstack(op_, s.n_rep_p, s.slab_p),
                [float(x) for x in losses])
