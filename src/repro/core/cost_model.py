"""System-profile cost model (paper §4.2, Eqs. 6–9, 12–13).

Computation delays follow the fitted power law
    T_f^(x)(B) = lambda_x * B^gamma_x * w_x / C_x          (Eq. 6, equal cores)
    T_b^(x)(B) = varphi_x * B^beta_x  * w_x / C_x          (Eq. 7)
    T_top^(a)(B) = (lambda'_a B^gamma'_a + varphi'_a B^beta'_a) w_a / C_a  (8)
and communication
    T_emb = E / B_b,  T_grad = G / B_b                      (Eq. 9)
Memory
    M(B) = M0 + rho * B^chi                                 (Eq. 12)

Default constants are the paper's Table 8 fits; `profiler.fit_constants`
re-fits them from timed probes of the actual jitted step on this host.
NOTE on the Table 8 exponents: they are NEGATIVE, i.e. lambda*B^gamma is the
*per-sample* time (Fig. 8 fits per-sample efficiency, which improves with
batch size).  The per-iteration delay is therefore B * lambda*B^gamma =
lambda * B^(1+gamma) * w / C; with gamma_a = -0.80 this gives ~0.014 s/iter
at B=256, matching the paper's measured epoch times (Table 3), whereas the
literal per-iteration reading would be off by ~100x.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional


@dataclass(frozen=True)
class CostConstants:
    """Calibrated constants (default).

    The paper's main experiments split features evenly and give both
    parties the same ten-layer bottom model, so per-batch work is nearly
    balanced (the active party adds only the two-layer top).  The defaults
    encode that balance; `TABLE8` below carries the paper's verbatim fits
    for planner-math fidelity tests.

    `scaling_exp` models sublinear per-worker core scaling
    (time ∝ (w/C)^scaling_exp): a single process cannot saturate a 32-core
    socket, which is exactly why the PS architecture helps — with
    scaling_exp = 1 Eq. 6 is recovered verbatim and worker count cancels
    out of party throughput.
    """
    lambda_a: float = 0.012
    gamma_a: float = -0.85
    lambda_p: float = 0.012
    gamma_p: float = -0.85
    lambda_a_top: float = 0.004     # lambda'_a (two-layer top: small)
    gamma_a_top: float = -0.85
    varphi_a: float = 0.045
    beta_a: float = -0.75
    varphi_p: float = 0.045
    beta_p: float = -0.75
    beta_a_top: float = -0.75       # beta'_a
    varphi_a_top: float = 0.008     # varphi'_a
    scaling_exp: float = 0.75
    # memory model (Eq. 12); chi shared
    m_a0: float = 256.0             # MB base
    m_p0: float = 256.0
    rho_a: float = 2.0              # MB per B^chi
    rho_p: float = 2.0
    chi: float = 1.0


#: the paper's Table 8 fits, verbatim (their 64-core XEON host)
TABLE8 = CostConstants(
    lambda_a=0.018, gamma_a=-0.8015, lambda_p=0.010, gamma_p=-1.0071,
    lambda_a_top=0.011, gamma_a_top=-0.7514, varphi_a=0.066, beta_a=-0.6069,
    varphi_p=0.038, beta_p=-1.0546, beta_a_top=-0.7834, varphi_a_top=0.072,
    scaling_exp=1.0,
)


@dataclass(frozen=True)
class PartyProfile:
    cores: int                      # C_x
    mem_per_worker_mb: float = 4096.0
    feature_dim: int = 250          # scales lambda/varphi (data heterogeneity)
    ref_feature_dim: int = 250


@dataclass(frozen=True)
class SystemProfile:
    active: PartyProfile
    passive: PartyProfile
    bandwidth_mbps: float = 1000.0  # B_b (MB/s here)
    emb_bytes_per_sample: float = 512.0   # E/B (128-dim fp32 embedding)
    grad_bytes_per_sample: float = 512.0  # G/B
    constants: CostConstants = field(default_factory=CostConstants)


class CostModel:
    """Evaluates all delay/memory terms for a (w_a, w_p, B) configuration."""

    def __init__(self, profile: SystemProfile):
        self.p = profile
        self.c = profile.constants

    # -- scaling for data heterogeneity: compute scales with feature dim ----
    def _scale(self, party: PartyProfile) -> float:
        return party.feature_dim / max(party.ref_feature_dim, 1)

    # -- Eq. 6/7/8 -----------------------------------------------------------
    def _w(self, w: int, cores: int) -> float:
        return (w / cores) ** self.c.scaling_exp

    def t_f_a(self, B: int, w_a: int) -> float:
        c = self.c
        return (c.lambda_a * self._scale(self.p.active) *
                B ** (1 + c.gamma_a) * self._w(w_a, self.p.active.cores))

    def t_f_p(self, B: int, w_p: int) -> float:
        c = self.c
        return (c.lambda_p * self._scale(self.p.passive) *
                B ** (1 + c.gamma_p) * self._w(w_p, self.p.passive.cores))

    def t_b_a(self, B: int, w_a: int) -> float:
        c = self.c
        return (c.varphi_a * self._scale(self.p.active) *
                B ** (1 + c.beta_a) * self._w(w_a, self.p.active.cores))

    def t_b_p(self, B: int, w_p: int) -> float:
        c = self.c
        return (c.varphi_p * self._scale(self.p.passive) *
                B ** (1 + c.beta_p) * self._w(w_p, self.p.passive.cores))

    def t_top_a(self, B: int, w_a: int) -> float:
        c = self.c
        return ((c.lambda_a_top * B ** (1 + c.gamma_a_top) +
                 c.varphi_a_top * B ** (1 + c.beta_a_top)) *
                self._w(w_a, self.p.active.cores))

    # -- Eq. 9 ----------------------------------------------------------------
    def t_emb(self, B: int) -> float:
        return (self.p.emb_bytes_per_sample * B / 1e6) / \
            (self.p.bandwidth_mbps)

    def t_grad(self, B: int) -> float:
        return (self.p.grad_bytes_per_sample * B / 1e6) / \
            (self.p.bandwidth_mbps)

    # -- Eq. 10 ----------------------------------------------------------------
    def t_active(self, B: int, w_a: int) -> float:
        return self.t_f_a(B, w_a) + self.t_b_a(B, w_a) + \
            self.t_top_a(B, w_a) + self.t_grad(B)

    def t_passive(self, B: int, w_p: int) -> float:
        return self.t_f_p(B, w_p) + self.t_b_p(B, w_p) + self.t_emb(B)

    # -- Eq. 14 objective --------------------------------------------------------
    def objective(self, w_a: int, w_p: int, B: int) -> float:
        comp_a = self.t_f_a(B, w_a) + self.t_b_a(B, w_a) + self.t_top_a(B, w_a)
        comp_p = self.t_f_p(B, w_p) + self.t_b_p(B, w_p)
        comm = self.t_emb(B) + self.t_grad(B)
        return max(comp_a, comp_p) + comm

    # -- Eq. 12/13 memory ----------------------------------------------------------
    def mem_a(self, B: int) -> float:
        return self.c.m_a0 + self.c.rho_a * B ** self.c.chi

    def mem_p(self, B: int) -> float:
        return self.c.m_p0 + self.c.rho_p * B ** self.c.chi

    def b_max(self) -> float:
        c = self.c
        ba = ((self.p.active.mem_per_worker_mb - c.m_a0) / c.rho_a) \
            ** (1.0 / c.chi)
        bp = ((self.p.passive.mem_per_worker_mb - c.m_p0) / c.rho_p) \
            ** (1.0 / c.chi)
        return min(ba, bp)
