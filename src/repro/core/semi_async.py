"""Intra-party semi-asynchronous PS mechanism (paper §4.1, Eq. 5).

    Delta_T_t = ceil( DT0/2 * tanh(2t/DT0 - 2) + DT0/2 )

Early in training the interval is small (~0-1 epochs: frequent sync for
stability); it ramps to DT0 (sparse sync for throughput).
"""
from __future__ import annotations

import math
from typing import List, Sequence

import jax
import numpy as np


def delta_t(t: int, dt0: int) -> int:
    """Synchronization interval at epoch t (Eq. 5)."""
    if dt0 <= 0:
        return 1
    v = dt0 / 2 * math.tanh(2 * t / dt0 - 2) + dt0 / 2
    return max(int(math.ceil(v)), 1)


def sync_epochs(total_epochs: int, dt0: int) -> List[int]:
    """Epochs at which the PS performs a global aggregation."""
    out, t = [], 0
    while t < total_epochs:
        step = delta_t(t, dt0)
        t += step
        if t <= total_epochs:
            out.append(t)
    return out


def aggregate(replicas: Sequence, weights=None):
    """PS aggregation: (weighted) average of worker replicas' pytrees."""
    n = len(replicas)
    if weights is None:
        weights = [1.0 / n] * n
    else:
        s = sum(weights)
        weights = [w / s for w in weights]

    def combine(*leaves):
        acc = leaves[0] * weights[0]
        for lf, w in zip(leaves[1:], weights[1:]):
            acc = acc + lf * w
        return acc

    return jax.tree.map(combine, *replicas)


def broadcast(agg, n: int) -> List:
    """PS broadcast: every worker receives the aggregated params."""
    return [jax.tree.map(lambda a: a, agg) for _ in range(n)]
