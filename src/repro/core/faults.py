"""Declarative fault plans for deterministic faulty-world simulation.

A `FaultPlan` describes *what goes wrong* in a run — replica crashes
with optional rejoin, straggler cadence drift (a time-varying speed
multiplier), and channel-message drop bursts — as plain frozen data.
`core.des.simulate` consumes it so every fault lands in the event log
deterministically under the run seed; everything downstream (the
schedule compiler, both replay engines, checkpointing) only ever sees
the event log, which is what makes faulty worlds replay bit-for-bit
across engines, lane packs and device counts (see
docs/architecture.md §Fault injection & failover).

Semantics by method:

* ``pubsub`` — a `CrashFault` is a true fail-stop at the worker's next
  scheduling point: the worker emits no events for its outage window
  (dead lanes fall out of the lowering as masked lanes), its pending
  jobs are taken over by the surviving pool (the shared job queue), and
  on rejoin it re-enters through the PS pull path at the next Eq. 5
  sync barrier with its staleness recorded on the ``rejoin`` event.
  `ChannelDropFault` bursts lose messages in transit; the deadline
  machinery absorbs them like evictions.
* paired methods (``vfl``, ``vfl_ps``, ``avfl``, ``avfl_ps``) — a crash
  is a *stall*: the strict pairing has no pool to absorb a fail-stop,
  so the worker goes unavailable for the window and every barrier
  partner waits (``stall``/``resume`` events; wall-time blows up, no
  work is lost).  This is exactly the contrast `benchmarks/chaos.py`
  measures.  Channel drops would deadlock the blocking handshakes and
  are rejected.

`StragglerFault` applies to every method: the replica's per-task time
is scaled by a multiplier ramping linearly from 1 to `factor` over
`ramp` time units starting at `start`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, fields
from typing import Dict, Tuple

SIDES = ("a", "p")
CHANNELS = ("emb", "grad")


@dataclass(frozen=True)
class CrashFault:
    """Fail-stop of one replica at sim time `at`, rejoining
    `rejoin_after` time units later (``math.inf`` = never: the replica
    is gone for the rest of the run)."""
    side: str                     # "a" (active) | "p" (passive)
    replica: int
    at: float
    rejoin_after: float = math.inf


@dataclass(frozen=True)
class StragglerFault:
    """Cadence drift: replica task time is multiplied by a factor that
    ramps linearly 1 -> `factor` over `ramp` time units from `start`
    and stays at `factor` afterwards (`ramp=0` = step change)."""
    side: str
    replica: int
    factor: float = 2.0
    start: float = 0.0
    ramp: float = 0.0


@dataclass(frozen=True)
class ChannelDropFault:
    """Lose messages in transit on one channel during a burst window:
    every `drop_every`-th message arriving in
    ``[start, start + duration)`` is dropped (`drop_every=1` drops
    all)."""
    channel: str                  # "emb" | "grad"
    start: float
    duration: float
    drop_every: int = 2


@dataclass(frozen=True)
class FaultPlan:
    """The full failure scenario of one run.  Hashable and immutable, so
    it participates in Session structural keys and schedule memo keys
    directly; `key()` is the canonical tuple form."""
    crashes: Tuple[CrashFault, ...] = ()
    stragglers: Tuple[StragglerFault, ...] = ()
    drops: Tuple[ChannelDropFault, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "crashes", tuple(self.crashes))
        object.__setattr__(self, "stragglers", tuple(self.stragglers))
        object.__setattr__(self, "drops", tuple(self.drops))
        for f in self.crashes + self.stragglers:
            if f.side not in SIDES:
                raise ValueError(f"side {f.side!r} not in {SIDES}")
            if f.replica < 0:
                raise ValueError("replica must be >= 0")
        for c in self.crashes:
            if c.rejoin_after <= 0:
                raise ValueError("rejoin_after must be > 0 (inf = never)")
        for s in self.stragglers:
            if s.factor <= 0:
                raise ValueError("straggler factor must be > 0")
            if s.ramp < 0:
                raise ValueError("straggler ramp must be >= 0")
        for d in self.drops:
            if d.channel not in CHANNELS:
                raise ValueError(f"channel {d.channel!r} not in {CHANNELS}")
            if d.drop_every < 1:
                raise ValueError("drop_every must be >= 1")

    @property
    def empty(self) -> bool:
        return not (self.crashes or self.stragglers or self.drops)

    def key(self) -> tuple:
        """Canonical hashable form (Session structural keys, schedule
        memo keys)."""
        return (tuple(tuple(getattr(c, f.name) for f in fields(c))
                      for c in self.crashes),
                tuple(tuple(getattr(s, f.name) for f in fields(s))
                      for s in self.stragglers),
                tuple(tuple(getattr(d, f.name) for f in fields(d))
                      for d in self.drops))

    def validate(self, method: str) -> None:
        """Method-dependent semantics checks (see module docstring)."""
        if method != "pubsub":
            if self.drops:
                raise ValueError(
                    "channel-drop faults require method='pubsub' (the "
                    "paired methods' blocking handshakes would deadlock)")
            for c in self.crashes:
                if math.isinf(c.rejoin_after):
                    raise ValueError(
                        "a never-rejoining crash requires method="
                        "'pubsub' (paired methods stall their barrier "
                        "partners forever)")

    # -- DES-side accessors ---------------------------------------------
    def crashes_for(self, side: str, replica: int
                    ) -> Tuple[CrashFault, ...]:
        return tuple(sorted((c for c in self.crashes
                             if c.side == side and c.replica == replica),
                            key=lambda c: c.at))

    def multiplier(self, side: str, replica: int, t: float) -> float:
        """Compound straggler slowdown for (side, replica) at time `t`."""
        m = 1.0
        for s in self.stragglers:
            if s.side != side or s.replica != replica:
                continue
            if t <= s.start:
                continue
            if s.ramp <= 0 or t >= s.start + s.ramp:
                m *= s.factor
            else:
                m *= 1.0 + (s.factor - 1.0) * (t - s.start) / s.ramp
        return m

    # -- JSON round trip (subprocess workers, benchmarks) ---------------
    def to_dict(self) -> Dict:
        return {
            "crashes": [c.__dict__.copy() for c in self.crashes],
            "stragglers": [s.__dict__.copy() for s in self.stragglers],
            "drops": [d.__dict__.copy() for d in self.drops],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultPlan":
        return cls(
            crashes=tuple(CrashFault(**c) for c in d.get("crashes", ())),
            stragglers=tuple(StragglerFault(**s)
                             for s in d.get("stragglers", ())),
            drops=tuple(ChannelDropFault(**x)
                        for x in d.get("drops", ())))


def live_sets(dead_a: set, dead_p: set, n_rep_a: int, n_rep_p: int):
    """Canonical live-replica snapshot for an aggregation boundary:
    ``None`` when every replica is live (the engines keep their
    byte-identical healthy aggregation path), else a
    ``(live_a, live_p)`` pair of canonical replica-index tuples.  Shared
    by the schedule compiler and the event engine so both derive the
    SAME subset from the same event stream."""
    if not dead_a and not dead_p:
        return None
    return (tuple(i for i in range(n_rep_a) if i not in dead_a),
            tuple(i for i in range(n_rep_p) if i not in dead_p))
