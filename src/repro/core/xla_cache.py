"""Persistent XLA compilation cache, shared across processes.

The compiled replay engine's jitted scan costs ~8s of XLA compile per
(engine spec, shapes) pair on CPU.  Within one process the runner cache
in `core.jit_pipeline` already dedupes that; across processes (pytest
runs, benchmark sweeps, repeated experiments) the compile is re-paid from
scratch unless JAX's persistent compilation cache is pointed at a stable
on-disk directory.  This module does exactly that, once, for the whole
process:

    from repro.core.xla_cache import enable_persistent_cache
    enable_persistent_cache()          # idempotent

Knobs (env):
  REPRO_XLA_CACHE=<dir>   cache directory (default ~/.cache/repro/xla)
  REPRO_XLA_CACHE=0       disable entirely

`CompiledReplayEngine` calls this on construction and `tests/conftest.py`
calls it at session start, so sweeps and CI pay each compile once per
machine rather than once per process.
"""
from __future__ import annotations

import os
from typing import Optional

_DISABLE = ("0", "off", "none", "false")
_state = {"done": False, "path": None}


def enable_persistent_cache(path: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at a stable directory.

    Returns the cache directory, or None when disabled/unsupported.
    Idempotent: only the first call configures anything."""
    if _state["done"]:
        return _state["path"]
    _state["done"] = True

    env = os.environ.get("REPRO_XLA_CACHE", "")
    if env.lower() in _DISABLE:
        return None
    if path is None:
        path = env or os.path.join(os.path.expanduser("~"), ".cache",
                                   "repro", "xla")
    try:
        import jax
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(path))
        # cache every entry, however small/fast — the point is CI and
        # sweep re-runs, where even a 1s compile is pure waste
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        # jax memoizes the backing file cache at the FIRST jit compile;
        # any compile before this call (data prep, model init) would
        # have pinned it to "no cache" — drop the memo so the cache
        # takes effect mid-process
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:          # old jax / read-only fs: run uncached
        return None
    _state["path"] = str(path)
    return _state["path"]
