"""Tiny deterministic discrete-event engine (simpy-lite, generator based).

Processes are generators that yield commands:
    ("sleep", dt)                     -> resumed with None after dt
    ("get", store)                    -> resumed with the item (blocking)
    ("get_timeout", store, timeout)   -> resumed with item or None (deadline)
Stores are FIFO buffers with optional capacity; a full put EVICTS the
oldest entry (the paper's channel-buffer semantics).  A store may carry
a `drop_filter` — a deterministic predicate consulted on every put —
modeling loss in transit (fault injection's channel-drop bursts):
filtered items are counted in `n_dropped` and never reach the buffer or
any waiter.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Any, Deque, Generator, List, Optional, Tuple


class Store:
    def __init__(self, engine: "Engine", capacity: Optional[int] = None):
        self.engine = engine
        self.capacity = capacity
        self.buf: Deque[Any] = deque()
        self.waiters: Deque[list] = deque()   # [gen, timeout_token]
        self.n_evicted = 0
        self.n_dropped = 0
        self.drop_filter = None               # callable(item) -> bool

    def put(self, item: Any) -> None:
        if self.drop_filter is not None and self.drop_filter(item):
            self.n_dropped += 1               # lost in transit
            return
        while self.waiters:
            waiter = self.waiters.popleft()
            gen, token = waiter
            if token is not None and token.get("fired"):
                continue                       # timed out already
            if token is not None:
                token["cancelled"] = True
            self.engine._resume_soon(gen, item)
            return
        if self.capacity is not None and len(self.buf) >= self.capacity:
            self.buf.popleft()
            self.n_evicted += 1
        self.buf.append(item)

    def try_get(self) -> Tuple[bool, Any]:
        if self.buf:
            return True, self.buf.popleft()
        return False, None

    def __len__(self):
        return len(self.buf)


class Engine:
    def __init__(self):
        self.now = 0.0
        self._heap: List = []
        self._seq = itertools.count()
        self.trace: List[Tuple] = []           # (time, tag, payload) log

    # -- scheduling ------------------------------------------------------
    def _push(self, t: float, fn, arg=None):
        heapq.heappush(self._heap, (t, next(self._seq), fn, arg))

    def _resume_soon(self, gen, value):
        self._push(self.now, ("resume", gen), value)

    def process(self, gen: Generator) -> None:
        self._push(self.now, ("resume", gen), None)

    def log(self, tag: str, **payload):
        self.trace.append((self.now, tag, payload))

    # -- run -------------------------------------------------------------
    def run(self, until: float = float("inf")) -> float:
        while self._heap:
            t, _, action, arg = heapq.heappop(self._heap)
            if t > until:
                self.now = until
                return self.now
            self.now = t
            kind, obj = action
            if kind == "timeout_fire":
                gen, token = obj
                if token.get("cancelled"):
                    continue
                token["fired"] = True
                self._step(gen, None)
            else:                               # resume
                self._step(obj, arg)
        return self.now

    def _step(self, gen, value):
        try:
            cmd = gen.send(value)
        except StopIteration:
            return
        op = cmd[0]
        if op == "sleep":
            self._push(self.now + cmd[1], ("resume", gen), None)
        elif op == "get":
            store = cmd[1]
            ok, item = store.try_get()
            if ok:
                self._resume_soon(gen, item)
            else:
                store.waiters.append([gen, None])
        elif op == "get_timeout":
            store, timeout = cmd[1], cmd[2]
            ok, item = store.try_get()
            if ok:
                self._resume_soon(gen, item)
            else:
                token = {"fired": False, "cancelled": False}
                store.waiters.append([gen, token])
                self._push(self.now + timeout, ("timeout_fire",
                                                (gen, token)), None)
        else:
            raise ValueError(op)
