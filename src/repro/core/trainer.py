"""Replays a DES event log with REAL JAX updates (Algorithm 1).

The DES decides *when* things happen; this trainer executes *what* happens
— passive forwards at stale replica params, active steps on buffered
embeddings, delayed passive backwards, PS aggregations — so convergence
under staleness/DP is measured, not assumed (DESIGN.md §3).

Aggregation policy by method (paper semantics):
  vfl      — single pair, no aggregation
  vfl_ps   — synchronous: aggregate replicas every round (w batches)
  avfl     — no PS: single shared params per party (hogwild updates)
  avfl_ps  — aggregate replicas every epoch
  pubsub   — semi-async: aggregate at the Eq. 5 Delta_T_t epoch marks

Both engines implement the `core.engines.ReplayEngine` protocol
(`stage_data` → `init_state` → `run_epoch`* → `finish`) over an explicit
immutable state pytree, so the trainer's replay loop, per-epoch
callbacks, and checkpoint save/resume are engine-agnostic:

  engine="compiled" (default) — the hot path.  `core.schedule` lowers the
      event log to a dense tick program; `core.jit_pipeline`'s
      `CompiledReplayEngine` runs it as one jitted lax.scan per epoch,
      replica-vmapped, with device-resident DP (fused cut-layer publish)
      and device-accumulated losses.  No per-event Python dispatch, no
      per-step host<->device round trips.
  engine="event" — the per-event Python loop
      (`core.engines.EventReplayEngine`), kept as the readable reference
      semantics and for parity testing.  Its DP publish routes through
      the same fused `tabular.publish_embedding` op as the compiled
      engine, and its Gaussian noise now comes from a counter-based
      `jax.random` stream keyed in `EventState` (see
      docs/architecture.md §DP), so DP checkpoint-resume is bit-for-bit
      on both engines.

For non-DP runs both engines produce the same losses/metrics for the
same seed (see tests/test_engine_parity.py); only wall-clock differs.
With DP enabled the clip/projection math is shared, but the noise
*streams* differ (per-event draws vs. per-tick lane blocks, and
different key folds), so per-run numbers diverge while the clip/sigma
semantics match.

Per-epoch **callbacks** replace the old hardcoded eval cadence: a
callback is any callable taking an `EpochContext`; it can evaluate on
its own schedule (`ctx.evaluate()`), stream metrics, checkpoint
(`ctx.state` round-trips through `checkpoint.store.save_state`), or
request early stop (`ctx.stop = True`).  `repro.api.callbacks` ships
the common ones.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import RunConfig, SimResult
from repro.core.engines import (EventReplayEngine, ReplayEngine,
                                replica_counts)
from repro.core.jit_pipeline import CompiledReplayEngine
from repro.core.schedule import compile_schedule
from repro.core.semi_async import aggregate
from repro.data.vertical import VerticalView
from repro.dp.gdp import GDPConfig, noise_sigma
from repro.models import tabular
from repro.optim.optimizers import adam

ENGINES = ("compiled", "event")


@dataclass
class TrainResult:
    metric_name: str
    history: List[float]              # per-epoch test metric
    losses: List[float]               # mean train loss per epoch
    final_metric: float
    staleness_mean: float
    n_updates: int
    lane_occupancy: float = 0.0       # compiled engine only (0 = event)
    n_ticks: int = 0                  # compiled engine only
    data_path: Optional[Dict] = None  # streaming staging stats (None =
                                      # resident path)

    def epochs_to_target(self, target: float, higher_better: bool) -> float:
        """Epochs until the test metric first reaches `target`, or
        ``math.inf`` if it never does — the same unreachable sentinel as
        `time_to_target`, so "reached on the last epoch" and "never
        reached" are distinguishable."""
        for i, v in enumerate(self.history):
            if (v >= target) if higher_better else (v <= target):
                return i + 1
        return math.inf


@dataclass
class EpochContext:
    """What a per-epoch callback sees.  `epoch` counts COMPLETED epochs
    (1-based).  `evaluate()` lazily computes the test metric at the
    replica-averaged params and caches it for this epoch, so several
    callbacks share one evaluation.  `in_history` is True once this
    epoch's metric has been appended to `history` (by the trainer's
    `eval_every_epoch` path or by a callback) — cadence callbacks check
    it to avoid double-appending.  Setting `stop = True` ends the
    replay after this epoch (the state remains finishable/resumable)."""
    epoch: int
    n_epochs: int
    state: object
    engine: ReplayEngine
    trainer: "VFLTrainer"
    history: List[float]
    stop: bool = False
    in_history: bool = False
    _metric: Optional[float] = None

    def evaluate(self) -> float:
        if self._metric is None:
            ta, tp = self.engine.params_mean(self.state)
            self._metric = self.trainer._metric(ta, tp)
        return self._metric


Callback = Callable[[EpochContext], None]


def _auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    # Mann-Whitney with average ranks for ties
    uniq, inv, counts = np.unique(scores, return_inverse=True,
                                  return_counts=True)
    avg_rank = np.cumsum(counts) - (counts - 1) / 2.0
    ranks = avg_rank[inv]
    pos = y_true == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) /
                 (n_pos * n_neg))


class VFLTrainer:
    def __init__(self, cfg: RunConfig, active: VerticalView,
                 passive: VerticalView, test_active: VerticalView,
                 test_passive: VerticalView, task: str, *,
                 lr: float = 1e-3, seed: int = 0, resnet: bool = False,
                 gdp: Optional[GDPConfig] = None, depth: int = 10,
                 disable_semi_async: bool = False,
                 stream_window_batches: Optional[int] = None):
        self.cfg = cfg
        self.task = task
        self.resnet = resnet
        self.depth = depth
        self.lr = lr
        self.gdp = gdp
        self.sigma = noise_sigma(gdp) if gdp else 0.0
        self.clip = gdp.clip if gdp else math.inf
        self.disable_semi_async = disable_semi_async
        # streaming knob: not-None opts this trainer into windowed
        # staging (labels and the batch table stay resident; features
        # may be shard stores or wrapped arrays — see data.shards)
        self.stream_window_batches = stream_window_batches
        self.Xa, self.Xp, self.y = active.X, passive.X, active.y
        self.tXa, self.tXp, self.ty = (test_active.X, test_passive.X,
                                       test_active.y)
        self.seed = seed
        key = jax.random.PRNGKey(seed)
        ka, kp, kt = jax.random.split(key, 3)

        # replica counts per method
        self.n_rep_a, self.n_rep_p = replica_counts(cfg.method, cfg.w_a,
                                                    cfg.w_p)

        def mk_a(k):
            kb, kt_ = jax.random.split(k)
            return {"bottom": tabular.init_bottom(kb, self.Xa.shape[1],
                                                  depth=depth),
                    "top": tabular.init_top(kt_)}

        # the PS broadcasts ONE initialization to all workers (replica
        # averaging of independently-initialized nets would be destructive)
        theta_a0 = mk_a(ka)
        theta_p0 = tabular.init_bottom(kp, self.Xp.shape[1], depth=depth)
        self.theta_a = [jax.tree.map(lambda x: x, theta_a0)
                        for _ in range(self.n_rep_a)]
        self.theta_p = [jax.tree.map(lambda x: x, theta_p0)
                        for _ in range(self.n_rep_p)]
        self.opt = adam(lr)
        self.opt_a = [self.opt.init(t) for t in self.theta_a]
        self.opt_p = [self.opt.init(t) for t in self.theta_p]
        self.version_p = [0] * self.n_rep_p
        self.staleness: List[int] = []
        self.n_updates = 0

    # ------------------------------------------------------------------
    @property
    def d_emb(self) -> int:
        return self.theta_p[0]["layers"][-1]["b"].shape[0]

    def hyper(self) -> Dict:
        """The runtime scalar dict {lr, clip, sigma} for `run_epoch` —
        the hyperparameters that are *arguments* of a replay, not part
        of a compiled engine (see core.jit_pipeline.EngineSpec)."""
        return {"lr": self.lr, "clip": self.clip, "sigma": self.sigma}

    # ------------------------------------------------------------------
    def make_engine(self, sim: SimResult, *, engine: str = "compiled",
                    pack: str = "segmented",
                    scatter_drop: bool = False) -> ReplayEngine:
        """Build a `ReplayEngine` for this trainer's config and event
        log.  The compiled engine is safe to cache and share across
        trainers of the same shape (the Session API does exactly that):
        params, seed and hyperparameters all enter per run."""
        if engine not in ENGINES:
            raise ValueError(f"engine {engine!r} not in {ENGINES}")
        if engine == "compiled":
            sched = compile_schedule(
                self.cfg, sim.events, n_rep_a=self.n_rep_a,
                n_rep_p=self.n_rep_p, n_samples=len(self.y),
                disable_semi_async=self.disable_semi_async, pack=pack)
            return CompiledReplayEngine(
                sched, task=self.task, resnet=self.resnet, clip=self.clip,
                sigma=self.sigma, lr=self.lr, seed=self.cfg.seed,
                scatter_drop=scatter_drop)
        return EventReplayEngine(
            self.cfg, sim.events, n_rep_a=self.n_rep_a,
            n_rep_p=self.n_rep_p, n_samples=len(self.y), task=self.task,
            resnet=self.resnet, clip=self.clip, sigma=self.sigma,
            lr=self.lr, seed=self.seed,
            disable_semi_async=self.disable_semi_async)

    # ------------------------------------------------------------------
    def replay(self, sim: SimResult, *, eval_every_epoch: bool = True,
               engine: str = "compiled", pack: str = "segmented",
               callbacks: Sequence[Callback] = (),
               scatter_drop: bool = False) -> TrainResult:
        """Execute the event log.  `engine="compiled"` (default) runs the
        jitted scan engine; `engine="event"` runs the per-event loop
        (reference semantics, used for parity testing).  `pack` selects
        the compiled engine's lane layout: "segmented" (default),
        "packed" or "dense" (see core.schedule).  `callbacks` run after
        every epoch (see `EpochContext`)."""
        return self.replay_with(self.make_engine(sim, engine=engine,
                                                 pack=pack,
                                                 scatter_drop=scatter_drop),
                                eval_every_epoch=eval_every_epoch,
                                callbacks=callbacks)

    def replay_with(self, eng: ReplayEngine, *,
                    eval_every_epoch: bool = True,
                    callbacks: Sequence[Callback] = (),
                    state=None, seed: Optional[int] = None) -> TrainResult:
        """Drive a prebuilt engine through the staged protocol.  `state`
        resumes a checkpointed replay from `state.epoch` (see
        `checkpoint.store.save_state` / `engine.load_state`); `seed`
        keys the device DP noise stream (default: the trainer's)."""
        cfg = self.cfg
        data = eng.stage_data(self.Xa, self.Xp, self.y,
                              window_batches=self.stream_window_batches)
        if state is None:
            # seed=None keeps each engine's own default noise keying
            # (compiled: the schedule cfg seed; event: the trainer seed)
            state = eng.init_state(
                self.theta_a, self.opt_a, self.theta_p, self.opt_p,
                self.d_emb, seed=seed)
        hyper = self.hyper()
        history: List[float] = []
        for e in range(int(state.epoch), cfg.n_epochs):
            state = eng.run_epoch(state, e, data, hyper)
            ctx = EpochContext(epoch=e + 1, n_epochs=cfg.n_epochs,
                               state=state, engine=eng, trainer=self,
                               history=history)
            if eval_every_epoch:
                history.append(ctx.evaluate())
                ctx.in_history = True
            for cb in callbacks:
                cb(ctx)
            if ctx.stop:
                break
        return self._finish_replay(eng, state, history,
                                   data_path=getattr(data, "stats", None))

    def _finish_replay(self, eng: ReplayEngine, state,
                       history: List[float], *,
                       data_path: Optional[Dict] = None) -> TrainResult:
        """Fold a finished (or early-stopped) replay state back into the
        trainer and build its `TrainResult`.  Shared by `replay_with`
        and the point-stacked sweep driver (`api.sweep`), which finishes
        each unstacked per-point state through its own trainer."""
        # executed active steps come from the state's per-epoch count
        # buckets, so an early-stopped or resumed replay reports what
        # actually ran (== the schedule pre-pass count on a full replay)
        executed = int(np.asarray(state.cnt_vec, dtype=np.float64).sum())
        (self.theta_a, self.opt_a, self.theta_p, self.opt_p,
         losses) = eng.finish(state)
        self.version_p = list(eng.versions_p)
        # staleness is the schedule-wide compile-time sequence (no
        # per-epoch attribution); on an early-stopped replay it covers
        # the full schedule, not the executed prefix
        self.staleness.extend(eng.staleness)
        self.n_updates += executed
        if not history:
            history.append(self.evaluate())
        metric = "auc" if self.task == "classification" else "rmse"
        sched = getattr(eng, "schedule", None)
        return TrainResult(
            metric_name=metric, history=history, losses=losses,
            final_metric=history[-1],
            staleness_mean=(float(np.mean(self.staleness))
                            if self.staleness else 0.0),
            n_updates=self.n_updates,
            lane_occupancy=sched.lane_occupancy() if sched else 0.0,
            n_ticks=sched.n_ticks if sched else 0,
            data_path=dict(data_path) if data_path else None)

    # ------------------------------------------------------------------
    def evaluate(self) -> float:
        theta_a = aggregate(self.theta_a) if self.n_rep_a > 1 \
            else self.theta_a[0]
        theta_p = aggregate(self.theta_p) if self.n_rep_p > 1 \
            else self.theta_p[0]
        return self._metric(theta_a, theta_p)

    def _metric(self, theta_a, theta_p) -> float:
        scores = np.asarray(tabular.predict(
            theta_a, theta_p, jnp.asarray(self.tXa), jnp.asarray(self.tXp),
            task=self.task, resnet=self.resnet))
        if self.task == "classification":
            return _auc(np.asarray(self.ty), scores)
        return float(np.sqrt(np.mean((scores - self.ty) ** 2)))
