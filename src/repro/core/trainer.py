"""Replays a DES event log with REAL JAX updates (Algorithm 1).

The DES decides *when* things happen; this trainer executes *what* happens
— passive forwards at stale replica params, active steps on buffered
embeddings, delayed passive backwards, PS aggregations — so convergence
under staleness/DP is measured, not assumed (DESIGN.md §3).

Aggregation policy by method (paper semantics):
  vfl      — single pair, no aggregation
  vfl_ps   — synchronous: aggregate replicas every round (w batches)
  avfl     — no PS: single shared params per party (hogwild updates)
  avfl_ps  — aggregate replicas every epoch
  pubsub   — semi-async: aggregate at the Eq. 5 Delta_T_t epoch marks

Two replay engines execute the log (`VFLTrainer.replay(engine=...)`):

  engine="compiled" (default) — the hot path.  `core.schedule` lowers the
      event log to a dense tick program; `core.jit_pipeline`'s
      `CompiledReplayEngine` runs it as one jitted lax.scan per epoch,
      replica-vmapped, with device-resident DP (fused cut-layer publish)
      and device-accumulated losses.  No per-event Python dispatch, no
      per-step host<->device round trips.
  engine="event" — the legacy per-event Python loop, kept as the
      readable reference semantics and for parity testing.  Its DP
      publish routes through the same fused `tabular.publish_embedding`
      op as the compiled engine; only the Gaussian noise is still drawn
      from the legacy host numpy rng (see docs/architecture.md §DP).

For non-DP runs both engines produce the same losses/metrics for the
same seed (see tests/test_engine_parity.py); only wall-clock differs.
With DP enabled the clip/projection math is shared, but the noise
*streams* differ (host numpy rng vs. JAX PRNG), so per-run numbers
diverge while the clip/sigma semantics match.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import RunConfig, SimResult
from repro.core.jit_pipeline import CompiledReplayEngine
from repro.core.schedule import compile_schedule
from repro.core.semi_async import aggregate, sync_epochs
from repro.data.synthetic import Dataset
from repro.data.vertical import VerticalView, batch_ids
from repro.dp.gdp import GDPConfig, noise_sigma
from repro.models import tabular
from repro.optim.optimizers import adam, apply_updates

ENGINES = ("compiled", "event")


@dataclass
class TrainResult:
    metric_name: str
    history: List[float]              # per-epoch test metric
    losses: List[float]               # mean train loss per epoch
    final_metric: float
    staleness_mean: float
    n_updates: int
    lane_occupancy: float = 0.0       # compiled engine only (0 = event)
    n_ticks: int = 0                  # compiled engine only

    def epochs_to_target(self, target: float, higher_better: bool) -> int:
        for i, v in enumerate(self.history):
            if (v >= target) if higher_better else (v <= target):
                return i + 1
        return len(self.history)


def _auc(y_true: np.ndarray, scores: np.ndarray) -> float:
    # Mann-Whitney with average ranks for ties
    uniq, inv, counts = np.unique(scores, return_inverse=True,
                                  return_counts=True)
    avg_rank = np.cumsum(counts) - (counts - 1) / 2.0
    ranks = avg_rank[inv]
    pos = y_true == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) /
                 (n_pos * n_neg))


class VFLTrainer:
    def __init__(self, cfg: RunConfig, active: VerticalView,
                 passive: VerticalView, test_active: VerticalView,
                 test_passive: VerticalView, task: str, *,
                 lr: float = 1e-3, seed: int = 0, resnet: bool = False,
                 gdp: Optional[GDPConfig] = None, depth: int = 10,
                 disable_semi_async: bool = False):
        self.cfg = cfg
        self.task = task
        self.resnet = resnet
        self.depth = depth
        self.lr = lr
        self.gdp = gdp
        self.sigma = noise_sigma(gdp) if gdp else 0.0
        self.clip = gdp.clip if gdp else math.inf
        self.disable_semi_async = disable_semi_async
        self.Xa, self.Xp, self.y = active.X, passive.X, active.y
        self.tXa, self.tXp, self.ty = (test_active.X, test_passive.X,
                                       test_active.y)
        self.rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed)
        ka, kp, kt = jax.random.split(key, 3)

        # replica counts per method
        m = cfg.method
        self.n_rep_a = 1 if m in ("vfl", "avfl") else cfg.w_a
        self.n_rep_p = 1 if m in ("vfl", "avfl") else cfg.w_p
        if m in ("vfl_ps", "avfl_ps"):
            self.n_rep_a = self.n_rep_p = min(cfg.w_a, cfg.w_p)

        def mk_a(k):
            kb, kt_ = jax.random.split(k)
            return {"bottom": tabular.init_bottom(kb, self.Xa.shape[1],
                                                  depth=depth),
                    "top": tabular.init_top(kt_)}

        # the PS broadcasts ONE initialization to all workers (replica
        # averaging of independently-initialized nets would be destructive)
        theta_a0 = mk_a(ka)
        theta_p0 = tabular.init_bottom(kp, self.Xp.shape[1], depth=depth)
        self.theta_a = [jax.tree.map(lambda x: x, theta_a0)
                        for _ in range(self.n_rep_a)]
        self.theta_p = [jax.tree.map(lambda x: x, theta_p0)
                        for _ in range(self.n_rep_p)]
        self.opt = adam(lr)
        self.opt_a = [self.opt.init(t) for t in self.theta_a]
        self.opt_p = [self.opt.init(t) for t in self.theta_p]
        self.version_p = [0] * self.n_rep_p
        self.staleness: List[int] = []
        self._emb_buf: Dict[int, tuple] = {}   # bid -> (z_p, rows, rep_p, ver)
        self._grad_buf: Dict[int, tuple] = {}  # bid -> (g_zp, rows, rep_p)
        self._epoch_ids: Dict[int, np.ndarray] = {}
        self.n_updates = 0

    # ------------------------------------------------------------------
    def _rows(self, bid: int) -> np.ndarray:
        ep = bid // self.cfg.n_batches
        b = bid % self.cfg.n_batches
        if ep not in self._epoch_ids:
            self._epoch_ids[ep] = batch_ids(
                len(self.y), self.cfg.batch_size, seed=self.cfg.seed,
                epoch=ep)
        return self._epoch_ids[ep][b % len(self._epoch_ids[ep])]

    def _rep(self, w: int, party: str) -> int:
        n = self.n_rep_a if party == "a" else self.n_rep_p
        return w % n

    # ------------------------------------------------------------------
    def replay(self, sim: SimResult, *, eval_every_epoch: bool = True,
               engine: str = "compiled", pack: str = "segmented"
               ) -> TrainResult:
        """Execute the event log.  `engine="compiled"` (default) runs the
        jitted scan engine; `engine="event"` runs the legacy per-event
        loop (reference semantics, used for parity testing).  `pack`
        selects the compiled engine's lane layout: "segmented" (default,
        phase-signature runs executed by cond-free per-signature tick
        bodies with fused flat optimizer updates), "packed" (uniform
        work-row lanes, the PR 2 baseline) or "dense" (the legacy
        one-lane-per-replica layout, kept for parity/benchmark
        baselines)."""
        if engine not in ENGINES:
            raise ValueError(f"engine {engine!r} not in {ENGINES}")
        if engine == "compiled":
            return self._replay_compiled(
                sim, eval_every_epoch=eval_every_epoch, pack=pack)
        return self._replay_event(sim, eval_every_epoch=eval_every_epoch)

    # ------------------------------------------------------------------
    def _replay_compiled(self, sim: SimResult, *,
                         eval_every_epoch: bool = True,
                         pack: str = "segmented") -> TrainResult:
        cfg = self.cfg
        sched = compile_schedule(
            cfg, sim.events, n_rep_a=self.n_rep_a, n_rep_p=self.n_rep_p,
            n_samples=len(self.y),
            disable_semi_async=self.disable_semi_async, pack=pack)
        eng = CompiledReplayEngine(
            sched, task=self.task, resnet=self.resnet, clip=self.clip,
            sigma=self.sigma, lr=self.lr, seed=cfg.seed)
        d_emb = self.theta_p[0]["layers"][-1]["b"].shape[0]
        data = eng.stage_data(self.Xa, self.Xp, self.y)
        state = eng.init_state(self.theta_a, self.opt_a,
                               self.theta_p, self.opt_p, d_emb)
        history: List[float] = []
        for e in range(cfg.n_epochs):
            state = eng.run_segment(state, e, data)
            if eval_every_epoch:
                ta, tp = eng.params_mean(state)
                history.append(self._metric(ta, tp))
        (self.theta_a, self.opt_a, self.theta_p, self.opt_p,
         losses) = eng.finish(state)
        self.version_p = list(sched.versions_p)
        self.staleness.extend(sched.staleness)
        self.n_updates += sched.n_updates
        if not history:
            history.append(self.evaluate())
        metric = "auc" if self.task == "classification" else "rmse"
        return TrainResult(
            metric_name=metric, history=history, losses=losses,
            final_metric=history[-1],
            staleness_mean=(float(np.mean(self.staleness))
                            if self.staleness else 0.0),
            n_updates=self.n_updates,
            lane_occupancy=sched.lane_occupancy(), n_ticks=sched.n_ticks)

    # ------------------------------------------------------------------
    def _replay_event(self, sim: SimResult, *,
                      eval_every_epoch: bool = True) -> TrainResult:
        cfg = self.cfg
        m = cfg.method
        sync_marks = set(sync_epochs(cfg.n_epochs, cfg.dt0))
        if self.disable_semi_async:                    # ablation: w/o ΔT
            sync_marks = set(range(1, cfg.n_epochs + 1))
        history, losses = [], []
        ep_loss, ep_count = 0.0, 0
        a_steps_total = 0
        round_size = min(cfg.w_a, cfg.w_p)
        epoch_of_step = lambda s: min(s // max(cfg.n_batches, 1),
                                      cfg.n_epochs - 1)
        cur_epoch = 0

        for t, kind, pl in sim.events:
            if kind == "p_fwd":
                bid, w = pl["bid"], pl["w"]
                rep = self._rep(w, "p")
                rows = self._rows(bid)
                if self.sigma > 0 or math.isfinite(self.clip):
                    # same fused DP publish as the compiled engine
                    # (projection+tanh+clip+noise via the cut-layer op);
                    # only the noise SOURCE stays host-side — the legacy
                    # numpy rng stream — so event-engine DP runs remain
                    # reproducible against pre-fusion results
                    noise = None
                    if self.sigma > 0:
                        d_emb = self.theta_p[rep]["layers"][-1]["b"].shape[0]
                        noise = jnp.asarray(self.rng.normal(
                            size=(len(rows), d_emb)).astype(np.float32))
                    z = tabular.publish_embedding(
                        self.theta_p[rep], jnp.asarray(self.Xp[rows]),
                        noise, clip=self.clip, sigma=self.sigma,
                        resnet=self.resnet)
                else:
                    z = tabular.passive_forward(
                        self.theta_p[rep], jnp.asarray(self.Xp[rows]),
                        resnet=self.resnet)
                self._emb_buf[bid] = (z, rows, rep, self.version_p[rep])
            elif kind == "a_step":
                bid, w = pl["bid"], pl["w"]
                if bid not in self._emb_buf:
                    continue                            # dropped upstream
                z, rows, rep_p, fwd_ver = self._emb_buf.pop(bid)
                rep = self._rep(w, "a")
                loss, g_a, g_z = tabular.active_step(
                    self.theta_a[rep], jnp.asarray(self.Xa[rows]), z,
                    jnp.asarray(self.y[rows]), task=self.task,
                    resnet=self.resnet)
                ups, self.opt_a[rep] = self.opt.update(
                    g_a, self.opt_a[rep], self.theta_a[rep])
                self.theta_a[rep] = apply_updates(self.theta_a[rep], ups)
                self._grad_buf[bid] = (g_z, rows, rep_p, fwd_ver)
                ep_loss += float(loss)
                ep_count += 1
                a_steps_total += 1
                self.n_updates += 1
                # --- synchronous VFL-PS: aggregate every round ---
                if m == "vfl_ps" and a_steps_total % round_size == 0:
                    self._aggregate_a()
            elif kind == "p_bwd":
                bid = pl["bid"]
                if bid not in self._grad_buf:
                    continue
                g_z, rows, rep_p, fwd_ver = self._grad_buf.pop(bid)
                self.staleness.append(self.version_p[rep_p] - fwd_ver)
                g_p = tabular.passive_backward(
                    self.theta_p[rep_p], jnp.asarray(self.Xp[rows]), g_z,
                    resnet=self.resnet)
                ups, self.opt_p[rep_p] = self.opt.update(
                    g_p, self.opt_p[rep_p], self.theta_p[rep_p])
                self.theta_p[rep_p] = apply_updates(self.theta_p[rep_p],
                                                    ups)
                self.version_p[rep_p] += 1
                if m == "vfl_ps" and self.version_p[rep_p] % \
                        max(round_size, 1) == 0:
                    self._aggregate_p()

            # epoch boundary bookkeeping (driven by completed a_steps)
            new_epoch = epoch_of_step(a_steps_total)
            if new_epoch > cur_epoch or (t == sim.events[-1][0] and
                                         kind == sim.events[-1][1]):
                for ep_done in range(cur_epoch + 1, new_epoch + 1):
                    if m == "avfl_ps" or (m == "pubsub" and
                                          ep_done in sync_marks):
                        self._aggregate_a()
                        self._aggregate_p()
                    losses.append(ep_loss / max(ep_count, 1))
                    ep_loss, ep_count = 0.0, 0
                    if eval_every_epoch:
                        history.append(self.evaluate())
                cur_epoch = new_epoch

        while len(losses) < cfg.n_epochs:
            losses.append(ep_loss / max(ep_count, 1))
            ep_loss, ep_count = 0.0, 0
            history.append(self.evaluate())
        if not history:
            history.append(self.evaluate())

        metric = "auc" if self.task == "classification" else "rmse"
        return TrainResult(
            metric_name=metric, history=history, losses=losses,
            final_metric=history[-1],
            staleness_mean=(float(np.mean(self.staleness))
                            if self.staleness else 0.0),
            n_updates=self.n_updates)

    # ------------------------------------------------------------------
    def _aggregate_a(self):
        agg = aggregate(self.theta_a)
        self.theta_a = [jax.tree.map(lambda x: x, agg)
                        for _ in range(self.n_rep_a)]

    def _aggregate_p(self):
        agg = aggregate(self.theta_p)
        self.theta_p = [jax.tree.map(lambda x: x, agg)
                        for _ in range(self.n_rep_p)]

    # ------------------------------------------------------------------
    def evaluate(self) -> float:
        theta_a = aggregate(self.theta_a) if self.n_rep_a > 1 \
            else self.theta_a[0]
        theta_p = aggregate(self.theta_p) if self.n_rep_p > 1 \
            else self.theta_p[0]
        return self._metric(theta_a, theta_p)

    def _metric(self, theta_a, theta_p) -> float:
        scores = np.asarray(tabular.predict(
            theta_a, theta_p, jnp.asarray(self.tXa), jnp.asarray(self.tXp),
            task=self.task, resnet=self.resnet))
        if self.task == "classification":
            return _auc(np.asarray(self.ty), scores)
        return float(np.sqrt(np.mean((scores - self.ty) ** 2)))
