"""Pub/Sub embedding & gradient channels (paper §4.1).

Two twins:

1. `PubSubBroker` — the runtime broker used by the discrete-event runtimes:
   per-batch-ID channels, FIFO buffers of capacity p (embeddings) / q
   (gradients) with oldest-entry eviction, timestamps, and the waiting-
   deadline mechanism (T_ddl).

2. `ChannelState` + pure functions — a jit-safe fixed-size ring-buffer
   pytree usable inside lax.scan (the multi-pod dry-run lowers this twin).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# runtime twin
# ---------------------------------------------------------------------------
@dataclass
class Message:
    batch_id: int
    payload: Any
    t_publish: float
    meta: dict = field(default_factory=dict)


class Channel:
    """FIFO buffer of bounded capacity; overflow evicts the OLDEST entry
    (stale-update protection, paper's Buffer Mechanism)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.buf: Deque[Message] = collections.deque()
        self.n_evicted = 0

    def publish(self, msg: Message) -> None:
        if len(self.buf) >= self.capacity:
            self.buf.popleft()          # FIFO eviction of the oldest
            self.n_evicted += 1
        self.buf.append(msg)

    def poll(self) -> Optional[Message]:
        return self.buf.popleft() if self.buf else None

    def peek_age(self, now: float) -> Optional[float]:
        return (now - self.buf[0].t_publish) if self.buf else None

    def __len__(self):
        return len(self.buf)


class PubSubBroker:
    """Topic space = {embedding, gradient} x batch_id."""

    def __init__(self, p: int = 5, q: int = 5, t_ddl: float = 10.0):
        self.p, self.q, self.t_ddl = p, q, t_ddl
        self.emb: Dict[int, Channel] = {}
        self.grad: Dict[int, Channel] = {}
        self.n_deadline_drops = 0
        self.bytes_published = 0.0

    def _get(self, kind: str, batch_id: int) -> Channel:
        store = self.emb if kind == "emb" else self.grad
        if batch_id not in store:
            store[batch_id] = Channel(self.p if kind == "emb" else self.q)
        return store[batch_id]

    def publish(self, kind: str, batch_id: int, payload: Any, now: float,
                nbytes: float = 0.0, **meta) -> None:
        self._get(kind, batch_id).publish(Message(batch_id, payload, now,
                                                  meta))
        self.bytes_published += nbytes

    def poll(self, kind: str, batch_id: int) -> Optional[Message]:
        return self._get(kind, batch_id).poll()

    def ready(self, kind: str, batch_id: int) -> bool:
        return len(self._get(kind, batch_id)) > 0

    def deadline_expired(self, wait_started: float, now: float) -> bool:
        """Waiting-deadline mechanism: subscriber gives up after T_ddl and
        the batch is re-assigned (counted; caller handles reassignment)."""
        if now - wait_started > self.t_ddl:
            self.n_deadline_drops += 1
            return True
        return False

    def stats(self) -> dict:
        return {
            "evicted": sum(c.n_evicted for c in list(self.emb.values()) +
                           list(self.grad.values())),
            "deadline_drops": self.n_deadline_drops,
            "bytes_published": self.bytes_published,
        }


# ---------------------------------------------------------------------------
# jit twin: fixed-size ring buffer as a pytree
# ---------------------------------------------------------------------------
def channel_init(capacity: int, item_shape: Tuple[int, ...],
                 dtype=jnp.float32) -> dict:
    return {
        "data": jnp.zeros((capacity,) + tuple(item_shape), dtype),
        "batch_id": jnp.full((capacity,), -1, jnp.int32),
        "t_pub": jnp.zeros((capacity,), jnp.float32),
        "head": jnp.zeros((), jnp.int32),   # oldest
        "size": jnp.zeros((), jnp.int32),
    }


def channel_publish(state: dict, item, batch_id, now) -> dict:
    cap = state["data"].shape[0]
    full = state["size"] >= cap
    # tail slot; if full we advance head (FIFO eviction)
    tail = (state["head"] + state["size"]) % cap
    data = jax.lax.dynamic_update_index_in_dim(state["data"], item, tail, 0)
    bids = state["batch_id"].at[tail].set(batch_id)
    tpub = state["t_pub"].at[tail].set(now)
    head = jnp.where(full, (state["head"] + 1) % cap, state["head"])
    size = jnp.where(full, state["size"], state["size"] + 1)
    return {"data": data, "batch_id": bids, "t_pub": tpub, "head": head,
            "size": size}


def channel_poll(state: dict):
    """Returns (new_state, item, batch_id, valid)."""
    cap = state["data"].shape[0]
    valid = state["size"] > 0
    item = jax.lax.dynamic_index_in_dim(state["data"], state["head"], 0,
                                        keepdims=False)
    bid = state["batch_id"][state["head"]]
    head = jnp.where(valid, (state["head"] + 1) % cap, state["head"])
    size = jnp.where(valid, state["size"] - 1, state["size"])
    new = dict(state, head=head, size=size)
    return new, item, jnp.where(valid, bid, -1), valid


# ---------------------------------------------------------------------------
# slot-addressed ring: the compiled-engine twin
# ---------------------------------------------------------------------------
# The schedule compiler (`core.schedule`) resolves FIFO order, eviction and
# buffer occupancy ahead of time and hands out explicit slot indices, so
# the device-resident ring degenerates to a dense array with masked
# scatter/gather — no head/size bookkeeping survives into the scan.

def slot_ring_init(n_slots: int, item_shape: Tuple[int, ...],
                   dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((n_slots,) + tuple(item_shape), dtype)


def slot_ring_write(ring: jnp.ndarray, slots: jnp.ndarray,
                    items: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Scatter `items[i] -> ring[slots[i]]` for valid lanes; invalid lanes
    are routed out of bounds and dropped."""
    idx = jnp.where(valid, slots, ring.shape[0])
    return ring.at[idx].set(items, mode="drop")


def slot_ring_read(ring: jnp.ndarray, slots: jnp.ndarray) -> jnp.ndarray:
    """Gather `ring[slots[i]]` per lane (invalid lanes read slot 0 and are
    masked by the caller)."""
    return ring[jnp.clip(slots, 0, ring.shape[0] - 1)]
