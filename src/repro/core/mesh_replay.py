"""Replica/point-axis mesh placement for the compiled replay engine.

The compiled engine's epoch runners are *already* pure data parallelism
over the leading stacked axis — replica lanes in a single run, sweep
points in a stacked run — with three exceptions that GSPMD resolves with
collectives: the slot rings (shared mailboxes between passive and active
lanes), the loss/count accumulators, and the aggregation mean at agg
ticks.  So sharding is done **by placement, not by rewriting**: the lane
axis of the carry gets a `NamedSharding` over a 1-D ``("replica",)``
mesh, everything cross-lane is replicated, and the cached jitted runners
are reused verbatim — XLA partitions the scan body and inserts the
collectives (the aggregation psum at `vfl_ps` agg ticks, plus the ring
exchange traffic), keeping per-device arithmetic bit-identical to the
single-device program (proven by `tests/test_mesh_replay.py`).

Lane layout (padding, slab balance, `*_rep` permutation) is the schedule
compiler's job: see `core.schedule.device_lower` / `SlabPlan`.  This
module only builds meshes and places pytrees; it knows the engine's
carry by *position* (the `TrainerState.carry` 9-tuple) so it stays
import-leaf under `core.jit_pipeline`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

REPLICA_AXIS = "replica"

# carry positions carrying the stacked lane axis (theta_a, opt_a,
# theta_p, opt_p); the rest — rings, loss/count accumulators, PRNG key —
# is cross-lane state and stays replicated
_LANE_FIELDS = 4


def make_replay_mesh(n_devices: Optional[int] = None) -> Mesh:
    """A 1-D ``("replica",)`` mesh over the first `n_devices` devices.

    On a single-device host, multi-device CPU runs need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` exported
    before jax is imported."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"n_devices must be >= 1, got {n}")
    if n > len(devs):
        raise ValueError(
            f"n_devices={n} but only {len(devs)} jax device(s) visible; "
            f"for CPU testing export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before importing "
            f"jax")
    return Mesh(np.asarray(devs[:n]), (REPLICA_AXIS,))


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """Leading axis split over the replica mesh axis."""
    return NamedSharding(mesh, P(REPLICA_AXIS))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated on every mesh device."""
    return NamedSharding(mesh, P())


def put_replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.device_put(tree, replicated_sharding(mesh))


def shard_carry(mesh: Mesh, carry: tuple) -> tuple:
    """Place a `TrainerState.carry` 9-tuple: param/optimizer stacks get
    the lane sharding on their leading (replica-lane) axis, rings and
    accumulators and the key are replicated.  The lane counts are padded
    to a device multiple by `schedule.device_lower`, so the split is
    always even."""
    lane = lane_sharding(mesh)
    rep = replicated_sharding(mesh)
    out = tuple(jax.device_put(x, lane) for x in carry[:_LANE_FIELDS])
    return out + tuple(jax.device_put(x, rep) for x in carry[_LANE_FIELDS:])


def shard_stacked_carry(mesh: Mesh, carry: tuple) -> tuple:
    """Place a point-stacked carry: every leaf has a leading point axis
    (the `stack_points` layout), so the whole tuple gets the lane
    sharding on axis 0.  Point counts must be a device multiple — the
    sweep runner pads groups before staging."""
    lane = lane_sharding(mesh)
    return tuple(jax.device_put(x, lane) for x in carry)


def shard_stacked_data(mesh: Mesh, data: tuple) -> tuple:
    """Place stacked staged data `(rows, Xa, Xp, Y)`: the batch-row
    table is shared by every point (replicated); the per-point feature
    and label stacks split on the point axis."""
    rows, *stacks = data
    lane = lane_sharding(mesh)
    rep = replicated_sharding(mesh)
    return (jax.device_put(rows, rep),) + \
        tuple(jax.device_put(x, lane) for x in stacks)


def count_collectives(hlo_text: str) -> Dict[str, int]:
    """Cross-device collective op counts in compiled HLO text — the
    benchmark's 'psum count'.  `all-reduce` is the aggregation psum (and
    the loss/count accumulator merges); `collective-permute`/`all-gather`
    is ring exchange traffic between passive and active slabs."""
    return {op: hlo_text.count(op)
            for op in ("all-reduce", "all-gather", "collective-permute",
                       "all-to-all", "reduce-scatter")}
