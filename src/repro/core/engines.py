"""Replay-engine protocol + the per-event reference engine.

Both replay engines execute a DES event log through the same staged
surface so the trainer, the Session API (`repro.api`) and the
checkpoint-resume path never care which one runs:

    data  = engine.stage_data(Xa, Xp, y)
    state = engine.init_state(theta_a, opt_a, theta_p, opt_p, d_emb,
                              seed=...)
    for e in range(state.epoch, n_epochs):
        state = engine.run_epoch(state, e, data, hyper)
    theta_a, opt_a, theta_p, opt_p, losses = engine.finish(state)

`state` is an explicit, immutable pytree (no hidden mutable replica
lists) that round-trips through `checkpoint.store.save_state` /
`restore_state` + `engine.load_state`, so training can stop after any
epoch and resume bit-for-bit on BOTH engines, DP included: each
engine's DP noise comes from a counter-based `jax.random` stream whose
key lives in the state (`TrainerState.key` / `EventState.key`), so a
restored checkpoint continues the exact noise sequence.

`hyper` is the runtime scalar dict {lr, clip, sigma}: hyperparameters
that only scale arithmetic are *arguments* of an epoch run, not part of
the engine, which is what lets a Session sweep reuse one compiled
engine across lr/dp_mu points (see `core.jit_pipeline.EngineSpec`).

Engines implementing the protocol:

* `core.jit_pipeline.CompiledReplayEngine` — the jitted scan hot path.
* `EventReplayEngine` (here) — the readable per-event Python loop,
  extracted from the legacy `VFLTrainer._replay_event`; kept as the
  reference semantics and for parity testing.  Its epoch slicing, the
  vfl_ps round barriers, the Eq. 5 sync-mark aggregations, staleness
  and the loss bucketing replicate the legacy loop exactly (see
  tests/test_engine_parity.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.des import RunConfig
from repro.core.faults import live_sets
from repro.core.schedule import _rows_table
from repro.core.semi_async import aggregate, sync_epochs
from repro.models import tabular
from repro.optim.optimizers import adam, apply_updates

# re-exported so `core.engines` is the one import site for the protocol
# (incl. the point-stacking helpers used by the stacked sweep driver)
from repro.core.jit_pipeline import (CompiledReplayEngine,  # noqa: F401
                                     TrainerState, WindowedData,
                                     point_state, stack_points,
                                     unstack_points)
from repro.data.shards import is_feature_source


class ReplayEngine(Protocol):
    """Staged replay surface shared by the compiled and event engines.

    Streaming contract: `Xa`/`Xp` may be `data.shards` feature sources
    (row-gatherable, not ndarray) instead of resident arrays.  The value
    `stage_data` returns is then an engine-private *window plan* rather
    than staged device arrays, and `run_epoch` consumes the epoch as a
    sequence of bounded staging windows — the compiled engine
    double-buffers a window ahead (`core.jit_pipeline.WindowedData`),
    the event engine gathers per event (each event IS a bounded
    window).  Either way the executed tick/event stream is identical to
    the resident path, so results stay bit-for-bit equal; `max_windows`
    (compiled engine) parks the state mid-epoch on a window boundary
    for checkpointing."""

    # bookkeeping resolved ahead of the replay (control flow only)
    staleness: List[int]
    n_updates: int
    versions_p: List[int]
    n_epochs: int

    def stage_data(self, Xa, Xp, y, *,
                   window_batches: Optional[int] = None) -> Any: ...

    def init_state(self, theta_a, opt_a, theta_p, opt_p, d_emb: int, *,
                   seed: Optional[int] = None) -> Any: ...

    def run_epoch(self, state, epoch: int, data,
                  hyper: Optional[Dict] = None) -> Any: ...

    def params_mean(self, state) -> tuple: ...

    def finish(self, state) -> tuple: ...

    def load_state(self, payload) -> Any: ...

    def export_state(self, state) -> Any:
        """Device-count-independent view of `state` for checkpointing:
        canonical replica order, engine-private lane padding stripped.
        Identity for engines without a device-lowered layout."""
        ...


def default_hyper(lr: float, clip: float, sigma: float) -> Dict:
    return {"lr": lr, "clip": clip, "sigma": sigma}


def replica_counts(method: str, w_a: int, w_p: int) -> Tuple[int, int]:
    """Per-party replica counts by method (paper semantics): single
    shared params for the PS-less methods, ID-locked equal pools for the
    synchronous PS pairings, full decoupled pools for pubsub."""
    n_rep_a = 1 if method in ("vfl", "avfl") else w_a
    n_rep_p = 1 if method in ("vfl", "avfl") else w_p
    if method in ("vfl_ps", "avfl_ps"):
        n_rep_a = n_rep_p = min(w_a, w_p)
    return n_rep_a, n_rep_p


class EventState(NamedTuple):
    """Explicit state of the per-event engine: per-replica param/opt
    lists, passive version counters, the executed-step counter, the
    per-epoch loss buckets, the in-flight embedding/gradient buffers
    (the pipeline content crossing an epoch boundary) and the DP noise
    PRNG key (a counter-based `jax.random` key split once per publish,
    mirroring the compiled engine's carry key — its presence in the
    state is what makes DP checkpoint-resume bit-for-bit here too)."""
    theta_a: List
    opt_a: List
    theta_p: List
    opt_p: List
    version_p: List[int]
    a_steps: int
    loss_vec: List[float]
    cnt_vec: List[int]
    emb_buf: Dict[int, tuple]     # bid -> (z, rep_p, fwd_version)
    grad_buf: Dict[int, tuple]    # bid -> (g_z, rep_p, fwd_version)
    key: Any = None               # DP noise PRNG key
    epoch: int = 0


class EventReplayEngine:
    """The legacy per-event Python loop behind the `ReplayEngine`
    protocol.  A host pre-pass over the log (control flow only — buffer
    hits, executed-step counts) resolves the epoch slicing, staleness
    and final version counters ahead of time, exactly like the schedule
    compiler does for the compiled engine; the numeric replay then runs
    one epoch slice per `run_epoch`."""

    def __init__(self, cfg: RunConfig, events: List[Tuple], *,
                 n_rep_a: int, n_rep_p: int, n_samples: int, task: str,
                 resnet: bool = False, clip: float = math.inf,
                 sigma: float = 0.0, lr: float = 1e-3, opt=None,
                 seed: int = 0, disable_semi_async: bool = False):
        self.cfg = cfg
        self.events = events
        self.n_rep_a, self.n_rep_p = n_rep_a, n_rep_p
        self.task, self.resnet = task, resnet
        self.hyper = default_hyper(lr, clip, sigma)
        self._opt = opt
        self._seed = seed
        self.n_epochs = cfg.n_epochs
        self.rows = _rows_table(cfg, n_samples)

        sync_marks = set(sync_epochs(cfg.n_epochs, cfg.dt0))
        if disable_semi_async:
            sync_marks = set(range(1, cfg.n_epochs + 1))
        self._sync_marks = sync_marks
        self._round_size = min(cfg.w_a, cfg.w_p)

        # --- control-flow pre-pass: epoch cuts, staleness, versions ---
        n_batches = max(cfg.n_batches, 1)
        emb: Dict[int, tuple] = {}
        grad: Dict[int, tuple] = {}
        version_p = [0] * n_rep_p
        staleness: List[int] = []
        a_steps = 0
        cur_epoch = 0
        cuts: List[int] = []
        aggs: List[bool] = []
        # fault lowering: replicas inside a crash outage when an epoch
        # boundary lands sit out that boundary's aggregation — the same
        # live-set snapshots, at the same positions in the same sorted
        # stream, as the schedule compiler derives (core.schedule._lower)
        dead_a: set = set()
        dead_p: set = set()
        lives: List[Optional[tuple]] = []
        rejoins: List[Tuple[str, int, float]] = []
        last_t, last_kind = (events[-1][0], events[-1][1]) if events \
            else (None, None)
        for i, (t, kind, pl) in enumerate(events):
            if kind == "p_fwd":
                emb[pl["bid"]] = (pl["w"] % n_rep_p,
                                  version_p[pl["w"] % n_rep_p])
            elif kind == "a_step":
                if pl["bid"] in emb:
                    grad[pl["bid"]] = emb.pop(pl["bid"])
                    a_steps += 1
            elif kind == "p_bwd":
                if pl["bid"] in grad:
                    rep_p, ver = grad.pop(pl["bid"])
                    staleness.append(version_p[rep_p] - ver)
                    version_p[rep_p] += 1
            elif kind == "crash":
                if pl["side"] == "a":
                    dead_a.add(pl["w"] % n_rep_a)
                else:
                    dead_p.add(pl["w"] % n_rep_p)
            elif kind == "rejoin":
                if pl["side"] == "a":
                    rep = pl["w"] % n_rep_a
                    dead_a.discard(rep)
                else:
                    rep = pl["w"] % n_rep_p
                    dead_p.discard(rep)
                rejoins.append((pl["side"], rep,
                                float(pl.get("stale", 0.0))))
            new_epoch = min(a_steps // n_batches, cfg.n_epochs - 1)
            if new_epoch > cur_epoch or (t == last_t and kind == last_kind):
                for ep_done in range(cur_epoch + 1, new_epoch + 1):
                    cuts.append(i + 1)
                    aggs.append(cfg.method == "avfl_ps" or
                                (cfg.method == "pubsub" and
                                 ep_done in sync_marks))
                    lives.append(live_sets(dead_a, dead_p,
                                           n_rep_a, n_rep_p))
                cur_epoch = new_epoch
        while len(cuts) < cfg.n_epochs:
            cuts.append(len(events))
            aggs.append(False)
            lives.append(live_sets(dead_a, dead_p, n_rep_a, n_rep_p))
        self._cuts, self._aggs = cuts, aggs
        self._live = lives
        self._final_live = live_sets(dead_a, dead_p, n_rep_a, n_rep_p)
        self.rejoins = rejoins
        self.staleness = staleness
        self.n_updates = a_steps
        self.versions_p = list(version_p)

    # -- staging ---------------------------------------------------------
    def stage_data(self, Xa, Xp, y, *,
                   window_batches: Optional[int] = None) -> tuple:
        """Feature sources (`data.shards`) pass through unchanged — the
        replay below gathers `Xp[rows]` per event, so the event engine
        streams inherently one batch at a time; `window_batches` is
        accepted for protocol compatibility and ignored."""
        def host(x):
            return x if is_feature_source(x) else np.asarray(x)
        return (self.rows, host(Xa), host(Xp), np.asarray(y))

    def init_state(self, theta_a, opt_a, theta_p, opt_p, d_emb: int, *,
                   seed: Optional[int] = None) -> EventState:
        n = self.cfg.n_epochs
        # counter-based jax.random noise key, split once per publish —
        # the same keying discipline as the compiled engine's carry key,
        # and like there it rides IN the state so DP resume is bitwise
        key0 = jax.random.fold_in(
            jax.random.PRNGKey(self._seed if seed is None else seed), 0xE7)
        return EventState(list(theta_a), list(opt_a), list(theta_p),
                          list(opt_p), [0] * self.n_rep_p, 0,
                          [0.0] * n, [0] * n, {}, {}, key=key0, epoch=0)

    def load_state(self, payload) -> EventState:
        f = list(payload)
        if len(f) == 11:
            # pre-key checkpoint layout (epoch at f[10], no PRNG key):
            # migrate by reseeding the noise key from (seed, epoch) —
            # the old resume semantics (clip/sigma only, not bitwise)
            epoch = int(f[10])
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self._seed), 0xE7),
                epoch)
        else:
            key, epoch = jnp.asarray(f[10]), int(f[11])
        return EventState(list(f[0]), list(f[1]), list(f[2]), list(f[3]),
                          [int(v) for v in f[4]], int(f[5]),
                          [float(v) for v in f[6]], [int(v) for v in f[7]],
                          dict(f[8]), dict(f[9]), key=key, epoch=epoch)

    # -- execution -------------------------------------------------------
    def run_epoch(self, state: EventState, epoch: int, data,
                  hyper: Optional[Dict] = None) -> EventState:
        cfg = self.cfg
        hyper = self.hyper if hyper is None else hyper
        lr = float(hyper["lr"])
        clip, sigma = float(hyper["clip"]), float(hyper["sigma"])
        opt = self._opt if self._opt is not None else adam(lr)
        rows_tab, Xa, Xp, Y = data
        n_batches = max(cfg.n_batches, 1)

        ta, oa = list(state.theta_a), list(state.opt_a)
        tp, op_ = list(state.theta_p), list(state.opt_p)
        version_p = list(state.version_p)
        a_steps = state.a_steps
        loss_vec, cnt_vec = list(state.loss_vec), list(state.cnt_vec)
        emb_buf, grad_buf = dict(state.emb_buf), dict(state.grad_buf)
        key = jnp.asarray(state.key)

        lo = self._cuts[epoch - 1] if epoch > 0 else 0
        hi = self._cuts[epoch]
        for t, kind, pl in self.events[lo:hi]:
            if kind == "p_fwd":
                bid, w = pl["bid"], pl["w"]
                rep = w % self.n_rep_p
                rows = rows_tab[bid % len(rows_tab)]
                if sigma > 0 or math.isfinite(clip):
                    # same fused DP publish as the compiled engine, and
                    # since PR 5 the same noise SOURCE discipline too: a
                    # counter-based jax.random stream keyed in the state
                    # (one split per publish), replacing the legacy host
                    # numpy rng — DP resume is bit-for-bit here as well
                    noise = None
                    if sigma > 0:
                        d_emb = tp[rep]["layers"][-1]["b"].shape[0]
                        key, sub = jax.random.split(key)
                        noise = jax.random.normal(
                            sub, (len(rows), d_emb), jnp.float32)
                    z = tabular.publish_embedding(
                        tp[rep], jnp.asarray(Xp[rows]), noise, clip=clip,
                        sigma=sigma, resnet=self.resnet)
                else:
                    z = tabular.passive_forward(
                        tp[rep], jnp.asarray(Xp[rows]), resnet=self.resnet)
                emb_buf[bid] = (z, rep, version_p[rep])
            elif kind == "a_step":
                bid, w = pl["bid"], pl["w"]
                if bid not in emb_buf:
                    continue                    # dropped upstream
                z, rep_p, fwd_ver = emb_buf.pop(bid)
                rep = w % self.n_rep_a
                rows = rows_tab[bid % len(rows_tab)]
                loss, g_a, g_z = tabular.active_step(
                    ta[rep], jnp.asarray(Xa[rows]), z,
                    jnp.asarray(Y[rows]), task=self.task,
                    resnet=self.resnet)
                ups, oa[rep] = opt.update(g_a, oa[rep], ta[rep])
                ta[rep] = apply_updates(ta[rep], ups)
                grad_buf[bid] = (g_z, rep_p, fwd_ver)
                a_steps += 1
                bucket = min((a_steps - 1) // n_batches, cfg.n_epochs - 1)
                loss_vec[bucket] += float(loss)
                cnt_vec[bucket] += 1
                # --- synchronous VFL-PS: aggregate every round ---
                if cfg.method == "vfl_ps" and \
                        a_steps % max(self._round_size, 1) == 0:
                    ta = _aggregate(ta)
            elif kind == "p_bwd":
                bid = pl["bid"]
                if bid not in grad_buf:
                    continue
                g_z, rep_p, fwd_ver = grad_buf.pop(bid)
                rows = rows_tab[bid % len(rows_tab)]
                g_p = tabular.passive_backward(
                    tp[rep_p], jnp.asarray(Xp[rows]), g_z,
                    resnet=self.resnet)
                ups, op_[rep_p] = opt.update(g_p, op_[rep_p], tp[rep_p])
                tp[rep_p] = apply_updates(tp[rep_p], ups)
                version_p[rep_p] += 1
                if cfg.method == "vfl_ps" and version_p[rep_p] % \
                        max(self._round_size, 1) == 0:
                    tp = _aggregate(tp)

        if self._aggs[epoch]:          # avfl_ps / pubsub Eq. 5 sync mark
            live = self._live[epoch]
            if live is None:           # healthy boundary: byte-identical
                ta = _aggregate(ta)    # to the pre-fault path
                tp = _aggregate(tp)
            else:                      # survivors pull among themselves;
                ta = _aggregate_live(ta, live[0])   # crashed replicas
                tp = _aggregate_live(tp, live[1])   # keep frozen params
        return EventState(ta, oa, tp, op_, version_p, a_steps,
                          loss_vec, cnt_vec, emb_buf, grad_buf,
                          key=key, epoch=epoch + 1)

    def export_state(self, state: EventState) -> EventState:
        """Identity — the event engine has no device-private layout."""
        return state

    def params_mean(self, state: EventState) -> tuple:
        def mean(reps, live):
            # evaluation averages survivors only — a crashed replica's
            # frozen params are not part of the served model.  An empty
            # live set (every replica failed-stop) degenerates to the
            # full mean: there is nothing better to serve.
            if live is not None and 0 < len(live) < len(reps):
                reps = [reps[i] for i in live]
            return aggregate(reps) if len(reps) > 1 else reps[0]
        fl = self._final_live
        th_a = mean(state.theta_a, None if fl is None else fl[0])
        th_p = mean(state.theta_p, None if fl is None else fl[1])
        return th_a, th_p

    def finish(self, state: EventState):
        losses = [l / max(c, 1) for l, c in zip(state.loss_vec,
                                                state.cnt_vec)]
        return (list(state.theta_a), list(state.opt_a),
                list(state.theta_p), list(state.opt_p), losses)


def _aggregate(replicas: List) -> List:
    agg = aggregate(replicas)
    return [jax.tree.map(lambda x: x, agg) for _ in range(len(replicas))]


def _aggregate_live(replicas: List, live: tuple) -> List:
    """PS pull restricted to the live subset: survivors aggregate among
    themselves (and a replica rejoining at this boundary pulls the
    survivor mean — its recorded staleness); dead replicas keep their
    frozen params until a boundary they are live at.  A full subset is
    routed through the healthy path so it stays byte-identical."""
    if len(live) == len(replicas):
        return _aggregate(replicas)
    if not live:
        return replicas               # whole party down: nothing to pull
    agg = aggregate([replicas[i] for i in live])
    out = list(replicas)
    for i in live:
        out[i] = jax.tree.map(lambda x: x, agg)
    return out
