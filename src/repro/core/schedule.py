"""Schedule compiler: lowers a DES event log to a dense tick program.

The DES (`core.des`) emits a *totally ordered* event log; the legacy
trainer replays it one Python-dispatched jit call per event.  This module
compiles the log, **once and entirely on the host**, into a small set of
dense per-tick arrays that a single jitted ``lax.scan`` (the compiled
engine in `core.jit_pipeline`) can execute with zero per-event Python.

Key observation: all *control* state of the replay — which replica runs
which batch, which published embedding an active step consumes, the
passive-parameter version at publish vs. backward time (= staleness), the
round/epoch aggregation points — depends only on the event log, never on
parameter values.  So the compiler resolves it ahead of time:

* Events are packed into **ticks**.  A tick holds at most one passive op
  (forward *or* backward) per passive replica and at most one active step
  per active replica; the engine vmaps each phase across replicas.  Ticks
  preserve every per-replica event order and every producer→consumer
  dependency (p_fwd before its a_step, a_step strictly before its p_bwd),
  so the packed program is numerically identical to the serial replay.
* In-flight embeddings/gradients are assigned **ring slots** (the
  device-resident twin of `core.channels`): a free-list simulation bounds
  the rings to the true peak buffer occupancy.
* `vfl_ps` round aggregations become per-tick barrier flags executed
  inside the scan; `avfl_ps`/`pubsub` Eq. 5 epoch aggregations become
  segment-boundary flags executed between scans.
* The log is cut into one **segment per epoch** (padded to a common
  length so the engine compiles exactly once); the trainer evaluates
  between segments, exactly where the event loop evaluated.
* Staleness and the update count are emitted by the compiler itself.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.des import RunConfig
from repro.core.semi_async import sync_epochs
from repro.data.vertical import batch_ids


# ---------------------------------------------------------------------------
# slot allocator: free-list simulation with availability ticks
# ---------------------------------------------------------------------------
class _SlotPool:
    """Assigns ring slots to in-flight payloads.

    A slot released at `avail` may be re-used by any event at tick >=
    `avail`; the engine's within-tick phase order (reads before writes for
    gradients, writes before reads for embeddings) dictates the caller's
    choice of `avail`."""

    def __init__(self):
        self.n = 0
        self._free: List[Tuple[int, int]] = []   # (avail_tick, slot)

    def alloc(self, tick: int) -> int:
        for i, (avail, slot) in enumerate(self._free):
            if avail <= tick:
                self._free.pop(i)
                return slot
        self.n += 1
        return self.n - 1

    def release(self, slot: int, avail: int) -> None:
        self._free.append((avail, slot))


# ---------------------------------------------------------------------------
# compiled schedule containers
# ---------------------------------------------------------------------------
@dataclass
class Segment:
    """One epoch's tick program (unpadded)."""
    pf_bid: np.ndarray      # (T, n_rep_p) int32, -1 = no-op lane
    pf_slot: np.ndarray     # (T, n_rep_p) int32 embedding-ring write slot
    pb_bid: np.ndarray      # (T, n_rep_p) int32, -1 = no-op lane
    pb_slot: np.ndarray     # (T, n_rep_p) int32 gradient-ring read slot
    as_bid: np.ndarray      # (T, n_rep_a) int32, -1 = no-op lane
    as_eslot: np.ndarray    # (T, n_rep_a) int32 embedding-ring read slot
    as_gslot: np.ndarray    # (T, n_rep_a) int32 gradient-ring write slot
    as_epoch: np.ndarray    # (T, n_rep_a) int32 loss bucket
    agg_a: np.ndarray       # (T,) bool  in-scan active-party aggregation
    agg_p: np.ndarray       # (T,) bool  in-scan passive-party aggregation
    epoch_agg: bool         # aggregate both parties after this segment


@dataclass
class CompiledSchedule:
    method: str
    n_rep_a: int
    n_rep_p: int
    n_epochs: int
    rows: np.ndarray               # (n_bids, B) int32 batch-row table
    segments: List[Segment]
    emb_slots: int                 # embedding ring size
    grad_slots: int                # gradient ring size
    staleness: List[int]           # precomputed (compile-time) staleness
    n_updates: int                 # executed active steps
    has_inscan_agg: bool           # any per-tick aggregation flag set
    versions_p: List[int] = field(default_factory=list)  # final versions

    @property
    def batch_rows(self) -> int:
        return int(self.rows.shape[1])

    @property
    def n_ticks(self) -> int:
        return sum(int(s.pf_bid.shape[0]) for s in self.segments)

    def padded(self) -> Dict[str, np.ndarray]:
        """Stack segments into (n_segments, T_max, ...) arrays padded with
        no-op ticks so one jit compilation covers every segment."""
        t_max = max((s.pf_bid.shape[0] for s in self.segments), default=0)
        t_max = max(t_max, 1)

        def pad(a: np.ndarray, fill) -> np.ndarray:
            out = np.full((t_max,) + a.shape[1:], fill, a.dtype)
            out[:a.shape[0]] = a
            return out

        keys = ("pf_bid", "pf_slot", "pb_bid", "pb_slot", "as_bid",
                "as_eslot", "as_gslot", "as_epoch", "agg_a", "agg_p")
        fills = {"pf_bid": -1, "pb_bid": -1, "as_bid": -1,
                 "agg_a": False, "agg_p": False}
        return {k: np.stack([pad(getattr(s, k), fills.get(k, 0))
                             for s in self.segments])
                for k in keys}


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------
def _rows_table(cfg: RunConfig, n_samples: int) -> np.ndarray:
    rows = []
    for ep in range(cfg.n_epochs):
        ids = batch_ids(n_samples, cfg.batch_size, seed=cfg.seed, epoch=ep)
        for b in range(cfg.n_batches):
            rows.append(ids[b % len(ids)])
    return np.asarray(rows, np.int32)


class _TickBuilder:
    def __init__(self, n_rep_a: int, n_rep_p: int):
        self.n_rep_a, self.n_rep_p = n_rep_a, n_rep_p
        self.ticks: List[dict] = []

    def _ensure(self, t: int) -> dict:
        while len(self.ticks) <= t:
            self.ticks.append({"pf": {}, "pb": {}, "as": {},
                               "agg_a": False, "agg_p": False})
        return self.ticks[t]

    def put(self, t: int, lane: str, rep: int, rec: tuple) -> None:
        self._ensure(t)[lane][rep] = rec

    def flag(self, t: int, which: str) -> None:
        self._ensure(t)[which] = True

    def slice(self, lo: int, hi: int) -> List[dict]:
        hi = min(hi, len(self.ticks))
        lo = min(lo, hi)
        return self.ticks[lo:hi]


def _materialize(ticks: List[dict], n_rep_a: int, n_rep_p: int,
                 epoch_agg: bool) -> Segment:
    T = len(ticks)
    z = lambda n: np.zeros((T, n), np.int32)
    neg = lambda n: np.full((T, n), -1, np.int32)
    seg = Segment(pf_bid=neg(n_rep_p), pf_slot=z(n_rep_p),
                  pb_bid=neg(n_rep_p), pb_slot=z(n_rep_p),
                  as_bid=neg(n_rep_a), as_eslot=z(n_rep_a),
                  as_gslot=z(n_rep_a), as_epoch=z(n_rep_a),
                  agg_a=np.zeros(T, bool), agg_p=np.zeros(T, bool),
                  epoch_agg=epoch_agg)
    for t, tk in enumerate(ticks):
        for rep, (bid, slot) in tk["pf"].items():
            seg.pf_bid[t, rep], seg.pf_slot[t, rep] = bid, slot
        for rep, (bid, slot) in tk["pb"].items():
            seg.pb_bid[t, rep], seg.pb_slot[t, rep] = bid, slot
        for rep, (bid, es, gs, ep) in tk["as"].items():
            seg.as_bid[t, rep] = bid
            seg.as_eslot[t, rep], seg.as_gslot[t, rep] = es, gs
            seg.as_epoch[t, rep] = ep
        seg.agg_a[t] = tk["agg_a"]
        seg.agg_p[t] = tk["agg_p"]
    return seg


def compile_schedule(cfg: RunConfig, events: List[Tuple], *,
                     n_rep_a: int, n_rep_p: int, n_samples: int,
                     disable_semi_async: bool = False) -> CompiledSchedule:
    """Lower an event log into a `CompiledSchedule`.

    Mirrors `VFLTrainer._replay_event` exactly: buffer hits/misses,
    replica routing (w % n_rep), version counters, vfl_ps round
    aggregation, the Eq. 5 sync marks, epoch/loss bucketing and the
    trailing-epoch flush all follow the same control flow, just resolved
    at compile time instead of replay time."""
    m = cfg.method
    n_batches = max(cfg.n_batches, 1)
    round_size = min(cfg.w_a, cfg.w_p)
    sync_marks = set(sync_epochs(cfg.n_epochs, cfg.dt0))
    if disable_semi_async:
        sync_marks = set(range(1, cfg.n_epochs + 1))

    rows = _rows_table(cfg, n_samples)
    tb = _TickBuilder(n_rep_a, n_rep_p)
    emb, grad = _SlotPool(), _SlotPool()
    next_a = [0] * n_rep_a
    next_p = [0] * n_rep_p
    global_max = -1
    emb_buf: Dict[int, tuple] = {}    # bid -> (rep_p, ver, slot, tick)
    grad_buf: Dict[int, tuple] = {}   # bid -> (rep_p, ver, slot, a_tick)
    version_p = [0] * n_rep_p
    staleness: List[int] = []
    a_steps_total = 0
    cur_epoch = 0
    cuts: List[Tuple[int, bool]] = []  # (exclusive tick bound, epoch_agg)
    has_inscan = False

    def barrier(t: int) -> None:
        for i in range(n_rep_a):
            next_a[i] = max(next_a[i], t)
        for i in range(n_rep_p):
            next_p[i] = max(next_p[i], t)

    last_t, last_kind = (events[-1][0], events[-1][1]) if events \
        else (None, None)

    for t_sim, kind, pl in events:
        if kind == "p_fwd":
            bid, w = pl["bid"], pl["w"]
            rep = w % n_rep_p
            t = next_p[rep]
            if bid in emb_buf:              # stale duplicate: discard old
                emb.release(emb_buf[bid][2], t + 1)
            slot = emb.alloc(t)
            tb.put(t, "pf", rep, (bid, slot))
            emb_buf[bid] = (rep, version_p[rep], slot, t)
            next_p[rep] = t + 1
            global_max = max(global_max, t)

        elif kind == "a_step":
            bid, w = pl["bid"], pl["w"]
            if bid in emb_buf:
                rep_p, ver, eslot, tf = emb_buf.pop(bid)
                rep = w % n_rep_a
                a_steps_total += 1
                trigger = (m == "vfl_ps" and
                           a_steps_total % max(round_size, 1) == 0)
                t = max(next_a[rep], tf)
                if trigger:
                    t = max(t, global_max)
                gslot = grad.alloc(t)
                bucket = min((a_steps_total - 1) // n_batches,
                             cfg.n_epochs - 1)
                tb.put(t, "as", rep, (bid, eslot, gslot, bucket))
                emb.release(eslot, t + 1)   # engine reads before next write
                grad_buf[bid] = (rep_p, ver, gslot, t)
                next_a[rep] = t + 1
                global_max = max(global_max, t)
                if trigger:
                    tb.flag(t, "agg_a")
                    has_inscan = True
                    barrier(t + 1)

        elif kind == "p_bwd":
            bid = pl["bid"]
            if bid in grad_buf:
                rep_p, ver, gslot, ta = grad_buf.pop(bid)
                staleness.append(version_p[rep_p] - ver)
                version_p[rep_p] += 1
                trigger = (m == "vfl_ps" and
                           version_p[rep_p] % max(round_size, 1) == 0)
                t = max(next_p[rep_p], ta + 1)
                if trigger:
                    t = max(t, global_max)
                tb.put(t, "pb", rep_p, (bid, gslot))
                grad.release(gslot, t)      # same-tick rewrite is phase-safe
                next_p[rep_p] = t + 1
                global_max = max(global_max, t)
                if trigger:
                    tb.flag(t, "agg_p")
                    has_inscan = True
                    barrier(t + 1)

        # epoch boundary bookkeeping — identical to the event loop's
        new_epoch = min(a_steps_total // n_batches, cfg.n_epochs - 1)
        if new_epoch > cur_epoch or (t_sim == last_t and kind == last_kind):
            for ep_done in range(cur_epoch + 1, new_epoch + 1):
                epoch_agg = (m == "avfl_ps" or
                             (m == "pubsub" and ep_done in sync_marks))
                cut = global_max + 1
                cuts.append((cut, epoch_agg))
                barrier(cut)
            cur_epoch = new_epoch

    # trailing epochs (the event loop's final while): leftover ticks land
    # in the first trailing segment; the rest are empty, never aggregated
    while len(cuts) < cfg.n_epochs:
        cuts.append((global_max + 1, False))

    segments, lo = [], 0
    for cut, epoch_agg in cuts[:cfg.n_epochs]:
        segments.append(_materialize(tb.slice(lo, cut), n_rep_a, n_rep_p,
                                     epoch_agg))
        lo = max(lo, cut)

    return CompiledSchedule(
        method=m, n_rep_a=n_rep_a, n_rep_p=n_rep_p, n_epochs=cfg.n_epochs,
        rows=rows, segments=segments, emb_slots=max(emb.n, 1),
        grad_slots=max(grad.n, 1), staleness=staleness,
        n_updates=a_steps_total, has_inscan_agg=has_inscan,
        versions_p=list(version_p))
