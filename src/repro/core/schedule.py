"""Schedule compiler: lowers a DES event log to a dense tick program.

The DES (`core.des`) emits a *totally ordered* event log; the legacy
trainer replays it one Python-dispatched jit call per event.  This module
compiles the log, **once and entirely on the host**, into a small set of
dense per-tick arrays that a single jitted ``lax.scan`` (the compiled
engine in `core.jit_pipeline`) can execute with zero per-event Python.
The tick-program format, the within-tick phase-ordering invariant and the
two lane layouts are documented in `docs/architecture.md`.

Key observation: all *control* state of the replay — which replica runs
which batch, which published embedding an active step consumes, the
passive-parameter version at publish vs. backward time (= staleness), the
round/epoch aggregation points — depends only on the event log, never on
parameter values.  So the compiler resolves it ahead of time:

* Events are packed into **ticks**.  A tick holds at most one passive op
  (forward *or* backward) per passive replica and at most one active step
  per active replica; the engine vmaps each phase across lanes.  Ticks
  preserve every per-replica event order and every producer→consumer
  dependency (p_fwd before its a_step, a_step strictly before its p_bwd),
  so the packed program is numerically identical to the serial replay.
* In-flight embeddings/gradients are assigned **ring slots** (the
  device-resident twin of `core.channels`): a free-list simulation bounds
  the rings to the true peak buffer occupancy.
* `vfl_ps` round aggregations become per-tick barrier flags executed
  inside the scan; `avfl_ps`/`pubsub` Eq. 5 epoch aggregations become
  segment-boundary flags executed between scans.
* The log is cut into one **segment per epoch** (padded to a common
  length so the engine compiles exactly once); the trainer evaluates
  between segments, exactly where the event loop evaluated.
* Staleness and the update count are emitted by the compiler itself.

Three lane layouts (``pack=``):

* ``"dense"`` — the legacy layout: one lane per replica per phase,
  ``(T, n_rep)`` arrays with ``-1`` marking idle lanes.  The engine runs
  every lane of every non-idle phase and masks the idle lanes, so
  executed-lane occupancy on asynchronous (`pubsub`) logs sits around
  55% (see `CompiledSchedule.lane_occupancy`).
* ``"packed"`` — dense tick packing: each phase gets a small
  fixed number of work lanes (its *steady-state* demand, ``ceil(ops /
  ticks)`` of a dense pre-pass) and every lane carries an explicit
  **replica index**.  The compiler re-times ops so no tick exceeds the
  lane budget; the engine gathers per-lane params from the stacked
  replica pytrees and scatters updates back by replica index
  (`optim.optimizers.packed_replica_update`), executing only occupied
  lanes.  Re-timing only ever *delays* an op, so every order constraint
  of the dense layout still holds and the decoded per-replica op
  sequences are identical (see `tests/test_schedule_pack.py`); tick
  indices and ring-slot numbers are layout-private.
* ``"segmented"`` (default) — segment-specialized packing: the packed
  tick stream is further partitioned into contiguous **runs** sharing a
  *phase signature* (which of pb/pf/as appear) and per-run lane widths.
  The engine compiles one **cond-free** tick body per signature — a
  phase a run never uses is simply not traced, so no `lax.cond`
  branch-unification carry copies — and chains the per-run scans inside
  one jitted epoch runner.  Per-run widths are chosen by a
  schedule-length-aware cost model (executed lane-slots + per-tick +
  per-run fixed overhead), recovering the warmup/drain bubbles that cap
  uniform-width occupancy at ~0.96; in-scan aggregation ticks keep
  their `lax.cond` only inside the runs that contain them.  Segmenting
  never re-times anything relative to ``"packed"`` — it is a pure
  re-grouping of the same tick stream, so the decoded per-replica op
  sequences are identical to both other layouts.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.des import RunConfig
from repro.core.faults import live_sets
from repro.core.semi_async import sync_epochs
from repro.data.vertical import batch_ids

PACKS = ("segmented", "packed", "dense")

PHASES = ("pb", "pf", "as")          # engine within-tick phase order


# ---------------------------------------------------------------------------
# slot allocator: free-list simulation with availability ticks
# ---------------------------------------------------------------------------
class _SlotPool:
    """Assigns ring slots to in-flight payloads.

    A slot released at `avail` may be re-used by any event at tick >=
    `avail`; the engine's within-tick phase order (reads before writes for
    gradients, writes before reads for embeddings) dictates the caller's
    choice of `avail`."""

    def __init__(self):
        self.n = 0
        self._free: List[Tuple[int, int]] = []   # (avail_tick, slot)

    def alloc(self, tick: int) -> int:
        for i, (avail, slot) in enumerate(self._free):
            if avail <= tick:
                self._free.pop(i)
                return slot
        self.n += 1
        return self.n - 1

    def release(self, slot: int, avail: int) -> None:
        self._free.append((avail, slot))


# ---------------------------------------------------------------------------
# compiled schedule containers
# ---------------------------------------------------------------------------
@dataclass
class Segment:
    """One epoch's tick program (unpadded), dense layout: lane j == replica
    j for every phase, idle lanes carry bid -1."""
    pf_bid: np.ndarray      # (T, n_rep_p) int32, -1 = no-op lane
    pf_slot: np.ndarray     # (T, n_rep_p) int32 embedding-ring write slot
    pb_bid: np.ndarray      # (T, n_rep_p) int32, -1 = no-op lane
    pb_slot: np.ndarray     # (T, n_rep_p) int32 gradient-ring read slot
    as_bid: np.ndarray      # (T, n_rep_a) int32, -1 = no-op lane
    as_eslot: np.ndarray    # (T, n_rep_a) int32 embedding-ring read slot
    as_gslot: np.ndarray    # (T, n_rep_a) int32 gradient-ring write slot
    as_epoch: np.ndarray    # (T, n_rep_a) int32 loss bucket
    agg_a: np.ndarray       # (T,) bool  in-scan active-party aggregation
    agg_p: np.ndarray       # (T,) bool  in-scan passive-party aggregation
    epoch_agg: bool         # aggregate both parties after this segment


@dataclass
class PackedSegment:
    """One epoch's tick program (unpadded), packed layout: a lane is a
    *work row*, not a replica — `*_rep` names the replica the lane's op
    belongs to (-1 = empty lane).  Each replica appears at most once per
    phase per tick, so the engine's scatter-back is conflict-free."""
    pf_rep: np.ndarray      # (T, L_pf) int32 replica index, -1 = empty
    pf_bid: np.ndarray      # (T, L_pf) int32 batch id
    pf_slot: np.ndarray     # (T, L_pf) int32 embedding-ring write slot
    pb_rep: np.ndarray      # (T, L_pb) int32 replica index, -1 = empty
    pb_bid: np.ndarray      # (T, L_pb) int32 batch id
    pb_slot: np.ndarray     # (T, L_pb) int32 gradient-ring read slot
    as_rep: np.ndarray      # (T, L_as) int32 replica index, -1 = empty
    as_bid: np.ndarray      # (T, L_as) int32 batch id
    as_eslot: np.ndarray    # (T, L_as) int32 embedding-ring read slot
    as_gslot: np.ndarray    # (T, L_as) int32 gradient-ring write slot
    as_epoch: np.ndarray    # (T, L_as) int32 loss bucket
    agg_a: np.ndarray       # (T,) bool  in-scan active-party aggregation
    agg_p: np.ndarray       # (T,) bool  in-scan passive-party aggregation
    epoch_agg: bool         # aggregate both parties after this segment


@dataclass
class Run:
    """A contiguous run of ticks sharing one phase signature.

    `sig` lists the phases (subset of PHASES, engine order) that the
    engine traces for this run — everything else is statically absent,
    so the run's tick body needs no per-phase `lax.cond`.  `arrays`
    holds the packed work rows for exactly the phases in `sig`, with
    this run's own lane widths (ticks inside a run may still have empty
    lanes, masked elementwise via rep == -1).  `has_agg` keeps the two
    in-scan aggregation conds (and the agg_a/agg_p flag arrays) only in
    runs that actually contain aggregation ticks."""
    sig: Tuple[str, ...]
    has_agg: bool
    arrays: Dict[str, np.ndarray]

    @property
    def n_ticks(self) -> int:
        for v in self.arrays.values():
            return int(v.shape[0])
        return 0

    @property
    def widths(self) -> Dict[str, int]:
        return {ph: int(self.arrays[f"{ph}_rep"].shape[1])
                for ph in self.sig}


@dataclass
class SegmentedSegment:
    """One epoch's tick program as a chain of signature runs.  Ticks with
    no work at all are dropped at materialization (they cannot carry
    aggregation flags: every agg tick contains the op that triggered
    it), so `n_ticks` counts executed ticks only."""
    runs: List[Run]
    epoch_agg: bool

    @property
    def n_ticks(self) -> int:
        return sum(r.n_ticks for r in self.runs)


_DENSE_KEYS = ("pf_bid", "pf_slot", "pb_bid", "pb_slot", "as_bid",
               "as_eslot", "as_gslot", "as_epoch", "agg_a", "agg_p")
_PACKED_KEYS = ("pf_rep", "pf_bid", "pf_slot", "pb_rep", "pb_bid",
                "pb_slot", "as_rep", "as_bid", "as_eslot", "as_gslot",
                "as_epoch", "agg_a", "agg_p")
_FILLS = {"pf_bid": -1, "pb_bid": -1, "as_bid": -1,
          "pf_rep": -1, "pb_rep": -1, "as_rep": -1,
          "agg_a": False, "agg_p": False}


@dataclass
class CompiledSchedule:
    method: str
    n_rep_a: int
    n_rep_p: int
    n_epochs: int
    rows: np.ndarray               # (n_bids, B) int32 batch-row table
    segments: List[Union[Segment, PackedSegment, SegmentedSegment]]
    emb_slots: int                 # embedding ring size
    grad_slots: int                # gradient ring size
    staleness: List[int]           # precomputed (compile-time) staleness
    n_updates: int                 # executed active steps
    has_inscan_agg: bool           # any per-tick aggregation flag set
    versions_p: List[int] = field(default_factory=list)  # final versions
    pack: str = "dense"            # lane layout: "packed" | "dense"
    lane_widths: Tuple[int, int, int] = (0, 0, 0)   # (L_pf, L_pb, L_as)
    slab_a: Optional["SlabPlan"] = None   # set by device_lower()
    slab_p: Optional["SlabPlan"] = None   # set by device_lower()
    # fault lowering (core.faults): per-segment live-replica snapshot at
    # the epoch boundary (None = all live, the healthy fast path), the
    # live set at end-of-log (params_mean aggregates survivors only),
    # and the (side, replica, staleness) record of every rejoin.  All in
    # CANONICAL replica indices even after device_lower() — the engines
    # translate to lanes through the slab plans.
    epoch_live: Tuple[Optional[tuple], ...] = ()
    final_live: Optional[tuple] = None
    rejoins: Tuple[Tuple[str, int, float], ...] = ()

    @property
    def batch_rows(self) -> int:
        return int(self.rows.shape[1])

    @property
    def n_ticks(self) -> int:
        if self.pack == "segmented":
            return sum(s.n_ticks for s in self.segments)
        return sum(int(s.agg_a.shape[0]) for s in self.segments)

    def n_ops(self) -> Tuple[int, int, int]:
        """Scheduled (p_fwd, p_bwd, a_step) op counts."""
        if self.pack == "segmented":
            return tuple(
                int(sum((r.arrays[f"{ph}_rep"] >= 0).sum()
                        for s in self.segments for r in s.runs
                        if ph in r.sig))
                for ph in ("pf", "pb", "as"))
        key = "rep" if self.pack == "packed" else "bid"
        return tuple(int(sum((getattr(s, f"{ph}_{key}") >= 0).sum()
                             for s in self.segments))
                     for ph in ("pf", "pb", "as"))

    def lane_occupancy(self) -> float:
        """Fraction of *executed* (tick, lane) slots doing real work —
        the compiled-engine analogue of the paper's utilization claim.

        The denominator mirrors each engine's actual lax.cond structure
        (padding ticks therefore never count).  The dense tick guards
        every phase separately, so a phase's lane width counts only in
        ticks where that phase has an active lane.  The packed tick runs
        both passive sub-phases under ONE cond (a deliberate
        carry-copy-saving choice), so both passive widths count in any
        tick where either passive phase is active.  The segmented engine
        has no conds at all: every run executes exactly the phases in
        its signature at its own lane widths, so the denominator is the
        sum of T_run * sum(widths) over runs.  The metric isolates what
        packing changes: how full the lanes are when a phase DOES run
        (~55% dense, ~91% packed, ~95% segmented on pubsub logs at the
        default objective; ≥98% segmented with width-1 caps pinned —
        see docs/architecture.md §occupancy for the speed trade)."""
        if self.pack == "segmented":
            work = slots = 0
            for seg in self.segments:
                for r in seg.runs:
                    for ph in r.sig:
                        work += int((r.arrays[f"{ph}_rep"] >= 0).sum())
                    slots += r.n_ticks * sum(r.widths.values())
            return work / slots if slots else 0.0
        key = "rep" if self.pack == "packed" else "bid"
        L_pf, L_pb, L_as = self.lane_widths
        work = slots = 0
        for seg in self.segments:
            pf = getattr(seg, f"pf_{key}") >= 0
            pb = getattr(seg, f"pb_{key}") >= 0
            as_ = getattr(seg, f"as_{key}") >= 0
            work += int(pf.sum()) + int(pb.sum()) + int(as_.sum())
            if self.pack == "packed":
                passive = pf.any(axis=1) | pb.any(axis=1)
                slots += (L_pf + L_pb) * int(passive.sum())
            else:
                slots += L_pf * int(pf.any(axis=1).sum()) + \
                    L_pb * int(pb.any(axis=1).sum())
            slots += L_as * int(as_.any(axis=1).sum())
        return work / slots if slots else 0.0

    def padded(self) -> Dict[str, np.ndarray]:
        """Stack segments into (n_segments, T_max, ...) arrays padded with
        no-op ticks so one jit compilation covers every segment.  The
        segmented layout has no common tick shape — its engine consumes
        `SegmentedSegment.runs` directly."""
        if self.pack == "segmented":
            raise ValueError("padded() is undefined for pack='segmented'; "
                             "iterate CompiledSchedule.segments[i].runs")
        t_max = max((s.agg_a.shape[0] for s in self.segments), default=0)
        t_max = max(t_max, 1)

        def pad(a: np.ndarray, fill) -> np.ndarray:
            out = np.full((t_max,) + a.shape[1:], fill, a.dtype)
            out[:a.shape[0]] = a
            return out

        keys = _PACKED_KEYS if self.pack == "packed" else _DENSE_KEYS
        return {k: np.stack([pad(getattr(s, k), _FILLS.get(k, 0))
                             for s in self.segments])
                for k in keys}


# ---------------------------------------------------------------------------
# the compiler
# ---------------------------------------------------------------------------
def _rows_table(cfg: RunConfig, n_samples: int) -> np.ndarray:
    rows = []
    for ep in range(cfg.n_epochs):
        ids = batch_ids(n_samples, cfg.batch_size, seed=cfg.seed, epoch=ep)
        for b in range(cfg.n_batches):
            rows.append(ids[b % len(ids)])
    return np.asarray(rows, np.int32)


class _TickBuilder:
    def __init__(self, n_rep_a: int, n_rep_p: int):
        self.n_rep_a, self.n_rep_p = n_rep_a, n_rep_p
        self.ticks: List[dict] = []

    def _ensure(self, t: int) -> dict:
        while len(self.ticks) <= t:
            self.ticks.append({"pf": {}, "pb": {}, "as": {},
                               "agg_a": False, "agg_p": False})
        return self.ticks[t]

    def put(self, t: int, lane: str, rep: int, rec: tuple) -> None:
        self._ensure(t)[lane][rep] = rec

    def flag(self, t: int, which: str) -> None:
        self._ensure(t)[which] = True

    def slice(self, lo: int, hi: int) -> List[dict]:
        hi = min(hi, len(self.ticks))
        lo = min(lo, hi)
        return self.ticks[lo:hi]


def _materialize_dense(ticks: List[dict], n_rep_a: int, n_rep_p: int,
                       epoch_agg: bool) -> Segment:
    T = len(ticks)
    z = lambda n: np.zeros((T, n), np.int32)
    neg = lambda n: np.full((T, n), -1, np.int32)
    seg = Segment(pf_bid=neg(n_rep_p), pf_slot=z(n_rep_p),
                  pb_bid=neg(n_rep_p), pb_slot=z(n_rep_p),
                  as_bid=neg(n_rep_a), as_eslot=z(n_rep_a),
                  as_gslot=z(n_rep_a), as_epoch=z(n_rep_a),
                  agg_a=np.zeros(T, bool), agg_p=np.zeros(T, bool),
                  epoch_agg=epoch_agg)
    for t, tk in enumerate(ticks):
        for rep, (bid, slot) in tk["pf"].items():
            seg.pf_bid[t, rep], seg.pf_slot[t, rep] = bid, slot
        for rep, (bid, slot) in tk["pb"].items():
            seg.pb_bid[t, rep], seg.pb_slot[t, rep] = bid, slot
        for rep, (bid, es, gs, ep) in tk["as"].items():
            seg.as_bid[t, rep] = bid
            seg.as_eslot[t, rep], seg.as_gslot[t, rep] = es, gs
            seg.as_epoch[t, rep] = ep
        seg.agg_a[t] = tk["agg_a"]
        seg.agg_p[t] = tk["agg_p"]
    return seg


def _materialize_packed(ticks: List[dict], widths: Tuple[int, int, int],
                        epoch_agg: bool) -> PackedSegment:
    T = len(ticks)
    L_pf, L_pb, L_as = widths
    z = lambda n: np.zeros((T, n), np.int32)
    neg = lambda n: np.full((T, n), -1, np.int32)
    seg = PackedSegment(
        pf_rep=neg(L_pf), pf_bid=neg(L_pf), pf_slot=z(L_pf),
        pb_rep=neg(L_pb), pb_bid=neg(L_pb), pb_slot=z(L_pb),
        as_rep=neg(L_as), as_bid=neg(L_as), as_eslot=z(L_as),
        as_gslot=z(L_as), as_epoch=z(L_as),
        agg_a=np.zeros(T, bool), agg_p=np.zeros(T, bool),
        epoch_agg=epoch_agg)
    for t, tk in enumerate(ticks):
        # replica-sorted lane fill keeps the layout deterministic
        for j, rep in enumerate(sorted(tk["pf"])):
            bid, slot = tk["pf"][rep]
            seg.pf_rep[t, j], seg.pf_bid[t, j] = rep, bid
            seg.pf_slot[t, j] = slot
        for j, rep in enumerate(sorted(tk["pb"])):
            bid, slot = tk["pb"][rep]
            seg.pb_rep[t, j], seg.pb_bid[t, j] = rep, bid
            seg.pb_slot[t, j] = slot
        for j, rep in enumerate(sorted(tk["as"])):
            bid, es, gs, ep = tk["as"][rep]
            seg.as_rep[t, j], seg.as_bid[t, j] = rep, bid
            seg.as_eslot[t, j], seg.as_gslot[t, j] = es, gs
            seg.as_epoch[t, j] = ep
        seg.agg_a[t] = tk["agg_a"]
        seg.agg_p[t] = tk["agg_p"]
    return seg


# ---------------------------------------------------------------------------
# segmented partitioning: signature runs with per-run lane widths
# ---------------------------------------------------------------------------
# Per-run fixed overhead, in lane-slot units (one slot = one vmapped net
# pass).  It prices what a run costs beyond its lane-slots — one more
# scan in the chained epoch runner, one more (signature, widths) body to
# trace — and so bounds fragmentation: a cut must save at least this
# many lane-slots to happen, and adjacent sig-runs cheaper merged than
# apart are merged.  Measured on the synthetic pubsub benchmark: finer
# partitioning (4 vs 16) lifted width-1 occupancy 0.95 -> 0.98 at equal
# wall-clock, so the constant sits at the low end.
_RUN_COST = 4


def _tick_counts(ticks: List[dict]) -> np.ndarray:
    """(T, len(PHASES)) per-tick op counts."""
    return np.array([[len(tk[ph]) for ph in PHASES] for tk in ticks],
                    np.int64).reshape(len(ticks), len(PHASES))


def _run_slots(counts: np.ndarray, lo: int, hi: int) -> int:
    """Executed lane-slots of ticks [lo, hi) as ONE run: every tick pays
    the run's per-phase max widths (its signature's union)."""
    return (hi - lo) * int(counts[lo:hi].max(axis=0).sum())


def _split_run(counts: np.ndarray, lo: int, hi: int,
               out: List[Tuple[int, int]]) -> None:
    """Best-split refinement: cut a run in two wherever the two sides'
    own max widths save more lane-slots than _RUN_COST — this is what
    peels warmup/drain ramps off the steady-state body.  Prefix/suffix
    running maxima make each level O(T); an explicit worklist (not
    recursion) keeps degenerate one-tick peels off the Python stack."""
    todo = [(lo, hi)]
    while todo:
        lo, hi = todo.pop()
        T = hi - lo
        if T < 2:
            out.append((lo, hi))
            continue
        seg = counts[lo:hi]
        pre = np.maximum.accumulate(seg, axis=0)
        suf = np.maximum.accumulate(seg[::-1], axis=0)[::-1]
        ks = np.arange(1, T)
        costs = ks * pre[:-1].sum(axis=1) + (T - ks) * suf[1:].sum(axis=1)
        k = int(np.argmin(costs))
        if int(costs[k]) + _RUN_COST < _run_slots(counts, lo, hi):
            todo.append((lo + k + 1, hi))
            todo.append((lo, lo + k + 1))
        else:
            out.append((lo, hi))


def _partition_runs(counts: np.ndarray,
                    sigs: List[tuple]) -> List[Tuple[int, int]]:
    """Partition a tick stream into signature runs minimizing
    lane-slots + _RUN_COST per run: exact-signature boundaries, then a
    greedy merge fixpoint (absorbs signature alternation that would
    fragment the chain), then recursive width splitting (recovers
    ramps inside long equal-signature stretches)."""
    T = len(sigs)
    bounds = [0] + [t for t in range(1, T) if sigs[t] != sigs[t - 1]] + [T]
    runs = list(zip(bounds[:-1], bounds[1:]))
    merged = True
    while merged:
        merged = False
        out: List[Tuple[int, int]] = []
        for lo, hi in runs:
            if out and _run_slots(counts, out[-1][0], hi) < \
                    _run_slots(counts, *out[-1]) + \
                    _run_slots(counts, lo, hi) + _RUN_COST:
                out[-1] = (out[-1][0], hi)
                merged = True
            else:
                out.append((lo, hi))
        runs = out
    final: List[Tuple[int, int]] = []
    for lo, hi in runs:
        _split_run(counts, lo, hi, final)
    return final


def _live_ticks(ticks: List[dict]) -> List[dict]:
    """Drop ticks with no work at all — they execute nothing (an agg
    flag always rides on the tick of the op that triggered it, but keep
    flagged ticks defensively)."""
    return [tk for tk in ticks
            if tk["pb"] or tk["pf"] or tk["as"]
            or tk["agg_a"] or tk["agg_p"]]


def _materialize_run(ticks: List[dict]) -> Run:
    T = len(ticks)
    widths = {ph: max((len(tk[ph]) for tk in ticks), default=0)
              for ph in PHASES}
    sig = tuple(ph for ph in PHASES if widths[ph] > 0)
    has_agg = any(tk["agg_a"] or tk["agg_p"] for tk in ticks)
    arrays: Dict[str, np.ndarray] = {}
    neg = lambda n: np.full((T, n), -1, np.int32)
    z = lambda n: np.zeros((T, n), np.int32)
    for ph in sig:
        L = widths[ph]
        arrays[f"{ph}_rep"] = neg(L)
        arrays[f"{ph}_bid"] = neg(L)
        if ph == "as":
            arrays["as_eslot"], arrays["as_gslot"] = z(L), z(L)
            arrays["as_epoch"] = z(L)
        else:
            arrays[f"{ph}_slot"] = z(L)
    for t, tk in enumerate(ticks):
        for ph in sig:
            for j, rep in enumerate(sorted(tk[ph])):
                arrays[f"{ph}_rep"][t, j] = rep
                if ph == "as":
                    bid, es, gs, ep = tk[ph][rep]
                    arrays["as_bid"][t, j] = bid
                    arrays["as_eslot"][t, j] = es
                    arrays["as_gslot"][t, j] = gs
                    arrays["as_epoch"][t, j] = ep
                else:
                    bid, slot = tk[ph][rep]
                    arrays[f"{ph}_bid"][t, j] = bid
                    arrays[f"{ph}_slot"][t, j] = slot
    if has_agg:
        arrays["agg_a"] = np.array([tk["agg_a"] for tk in ticks], bool)
        arrays["agg_p"] = np.array([tk["agg_p"] for tk in ticks], bool)
    return Run(sig=sig, has_agg=has_agg, arrays=arrays)


def _materialize_segmented(ticks: List[dict],
                           epoch_agg: bool) -> SegmentedSegment:
    keep = _live_ticks(ticks)
    if not keep:
        return SegmentedSegment(runs=[], epoch_agg=epoch_agg)
    counts = _tick_counts(keep)
    sigs = [tuple(ph for ph in PHASES if tk[ph]) for tk in keep]
    parts = _partition_runs(counts, sigs)
    return SegmentedSegment(
        runs=[_materialize_run(keep[lo:hi]) for lo, hi in parts],
        epoch_agg=epoch_agg)


@dataclass
class _Lowered:
    """Raw result of one scheduling pass, before materialization."""
    tb: _TickBuilder
    cuts: List[Tuple[int, bool]]
    emb_slots: int
    grad_slots: int
    staleness: List[int]
    n_updates: int
    has_inscan: bool
    versions_p: List[int]
    epoch_live: List[Optional[tuple]]
    final_live: Optional[tuple]
    rejoins: List[Tuple[str, int, float]]


def _lower(cfg: RunConfig, events: List[Tuple], *, n_rep_a: int,
           n_rep_p: int, disable_semi_async: bool,
           caps: Optional[Dict[str, int]] = None) -> _Lowered:
    """One scheduling pass over the event log.

    Mirrors `VFLTrainer._replay_event` exactly: buffer hits/misses,
    replica routing (w % n_rep), version counters, vfl_ps round
    aggregation, the Eq. 5 sync marks, epoch/loss bucketing and the
    trailing-epoch flush all follow the same control flow, just resolved
    at compile time instead of replay time.

    `caps` (packed layout) bounds the number of ops per phase per tick:
    an op whose earliest tick is full spills to the next tick with a free
    lane.  Spilling only ever *delays* an op, so every "happens-before"
    constraint of the uncapped pass still holds.

    The capped pass additionally fuses a passive replica's p_bwd with its
    *next* p_fwd into one tick when they are adjacent: the engine runs
    the backward phase before the forward phase within a tick, so
    "update, then publish at the updated params" executes in exactly the
    event order — this halves the passive per-replica tick chain (the
    steady-state alternation) and is what lets the packed program reach
    the dense layout's tick count at a third of its lane width.  The
    dense layout cannot express it (one lane per replica per tick), so
    fusion is gated on `caps`."""
    m = cfg.method
    n_batches = max(cfg.n_batches, 1)
    round_size = min(cfg.w_a, cfg.w_p)
    sync_marks = set(sync_epochs(cfg.n_epochs, cfg.dt0))
    if disable_semi_async:
        sync_marks = set(range(1, cfg.n_epochs + 1))

    tb = _TickBuilder(n_rep_a, n_rep_p)
    emb, grad = _SlotPool(), _SlotPool()
    next_a = [0] * n_rep_a
    next_p = [0] * n_rep_p
    global_max = -1
    emb_buf: Dict[int, tuple] = {}    # bid -> (rep_p, ver, slot, tick)
    grad_buf: Dict[int, tuple] = {}   # bid -> (rep_p, ver, slot, a_tick)
    version_p = [0] * n_rep_p
    staleness: List[int] = []
    a_steps_total = 0
    cur_epoch = 0
    cuts: List[Tuple[int, bool]] = []  # (exclusive tick bound, epoch_agg)
    has_inscan = False
    # fault bookkeeping: replicas inside a crash outage when an epoch
    # boundary lands are excluded from that boundary's aggregation (they
    # rejoin through the PS pull at the NEXT boundary they survive to).
    # The event engine's pre-pass walks the identical sorted stream and
    # snapshots at the identical cut positions, so both engines derive
    # the same live sets from the same log.
    dead_a: set = set()
    dead_p: set = set()
    epoch_live: List[Optional[tuple]] = []
    rejoins: List[Tuple[str, int, float]] = []
    used: Dict[str, Dict[int, int]] = {"pf": {}, "pb": {}, "as": {}}
    pb_fusable = [-1] * n_rep_p   # tick of rep's latest p_bwd, if its
    #                               next op may still fuse onto that tick

    def place(ph: str, t: int) -> int:
        """Earliest tick >= t with a free `ph` lane under the cap."""
        if caps is not None:
            cap = caps[ph]
            while used[ph].get(t, 0) >= cap:
                t += 1
        used[ph][t] = used[ph].get(t, 0) + 1
        return t

    def barrier(t: int) -> None:
        for i in range(n_rep_a):
            next_a[i] = max(next_a[i], t)
        for i in range(n_rep_p):
            next_p[i] = max(next_p[i], t)
            pb_fusable[i] = -1   # no fusing backward across a barrier

    last_t, last_kind = (events[-1][0], events[-1][1]) if events \
        else (None, None)

    for t_sim, kind, pl in events:
        if kind == "p_fwd":
            bid, w = pl["bid"], pl["w"]
            rep = w % n_rep_p
            t0 = next_p[rep]
            if caps is not None and pb_fusable[rep] == t0 - 1 >= 0:
                t0 -= 1                     # fuse onto the p_bwd's tick
            t = place("pf", t0)
            pb_fusable[rep] = -1
            if bid in emb_buf:              # stale duplicate: discard old
                emb.release(emb_buf[bid][2], t + 1)
            slot = emb.alloc(t)
            tb.put(t, "pf", rep, (bid, slot))
            emb_buf[bid] = (rep, version_p[rep], slot, t)
            next_p[rep] = t + 1
            global_max = max(global_max, t)

        elif kind == "a_step":
            bid, w = pl["bid"], pl["w"]
            if bid in emb_buf:
                rep_p, ver, eslot, tf = emb_buf.pop(bid)
                rep = w % n_rep_a
                a_steps_total += 1
                trigger = (m == "vfl_ps" and
                           a_steps_total % max(round_size, 1) == 0)
                t = max(next_a[rep], tf)
                if trigger:
                    t = max(t, global_max)
                t = place("as", t)
                gslot = grad.alloc(t)
                bucket = min((a_steps_total - 1) // n_batches,
                             cfg.n_epochs - 1)
                tb.put(t, "as", rep, (bid, eslot, gslot, bucket))
                emb.release(eslot, t + 1)   # engine reads before next write
                grad_buf[bid] = (rep_p, ver, gslot, t)
                next_a[rep] = t + 1
                global_max = max(global_max, t)
                if trigger:
                    tb.flag(t, "agg_a")
                    has_inscan = True
                    barrier(t + 1)

        elif kind == "p_bwd":
            bid = pl["bid"]
            if bid in grad_buf:
                rep_p, ver, gslot, ta = grad_buf.pop(bid)
                staleness.append(version_p[rep_p] - ver)
                version_p[rep_p] += 1
                trigger = (m == "vfl_ps" and
                           version_p[rep_p] % max(round_size, 1) == 0)
                t = max(next_p[rep_p], ta + 1)
                if trigger:
                    t = max(t, global_max)
                t = place("pb", t)
                tb.put(t, "pb", rep_p, (bid, gslot))
                grad.release(gslot, t)      # same-tick rewrite is phase-safe
                next_p[rep_p] = t + 1
                pb_fusable[rep_p] = t
                global_max = max(global_max, t)
                if trigger:
                    tb.flag(t, "agg_p")
                    has_inscan = True
                    barrier(t + 1)

        elif kind == "crash":
            if pl["side"] == "a":
                dead_a.add(pl["w"] % n_rep_a)
            else:
                dead_p.add(pl["w"] % n_rep_p)

        elif kind == "rejoin":
            if pl["side"] == "a":
                rep = pl["w"] % n_rep_a
                dead_a.discard(rep)
            else:
                rep = pl["w"] % n_rep_p
                dead_p.discard(rep)
            rejoins.append((pl["side"], rep, float(pl.get("stale", 0.0))))

        # epoch boundary bookkeeping — identical to the event loop's
        new_epoch = min(a_steps_total // n_batches, cfg.n_epochs - 1)
        if new_epoch > cur_epoch or (t_sim == last_t and kind == last_kind):
            for ep_done in range(cur_epoch + 1, new_epoch + 1):
                epoch_agg = (m == "avfl_ps" or
                             (m == "pubsub" and ep_done in sync_marks))
                cut = global_max + 1
                cuts.append((cut, epoch_agg))
                epoch_live.append(live_sets(dead_a, dead_p,
                                            n_rep_a, n_rep_p))
                barrier(cut)
            cur_epoch = new_epoch

    # trailing epochs (the event loop's final while): leftover ticks land
    # in the first trailing segment; the rest are empty, never aggregated
    while len(cuts) < cfg.n_epochs:
        cuts.append((global_max + 1, False))
        epoch_live.append(live_sets(dead_a, dead_p, n_rep_a, n_rep_p))

    return _Lowered(tb=tb, cuts=cuts, emb_slots=max(emb.n, 1),
                    grad_slots=max(grad.n, 1), staleness=staleness,
                    n_updates=a_steps_total, has_inscan=has_inscan,
                    versions_p=list(version_p),
                    epoch_live=epoch_live,
                    final_live=live_sets(dead_a, dead_p, n_rep_a, n_rep_p),
                    rejoins=rejoins)


def _cap_candidates(low: _Lowered, n_rep_a: int,
                    n_rep_p: int) -> List[Dict[str, int]]:
    """Per-phase lane-budget candidates bracketing the steady-state
    demand of the dense pre-pass (floor/ceil of ops-per-tick), plus the
    full dense widths as a fallback.  Capping near the average is what
    forces bursty ticks to spill into the idle ones and drives occupancy
    toward 1; the spill cost is bounded by the burstiness of the log.
    The dense-width candidate wins on short bursty programs (tiny test
    configs) where spilling costs more than it saves."""
    T = max(len(low.tb.ticks), 1)
    per_phase = []
    for ph, n_rep in (("pf", n_rep_p), ("pb", n_rep_p), ("as", n_rep_a)):
        mean = sum(len(tk[ph]) for tk in low.tb.ticks) / T
        per_phase.append(sorted({max(1, math.floor(mean)),
                                 max(1, math.ceil(mean)), n_rep}))
    return [dict(zip(("pf", "pb", "as"), combo))
            for combo in itertools.product(*per_phase)]


def _segmented_cost(low: _Lowered, n_epochs: int,
                    batch_size: int) -> float:
    """Modeled execution cost of a capped lowering under segmented
    execution: executed lane-slots after run partitioning, plus a
    per-executed-tick fixed charge (scan-step overhead — the full-stack
    scatter merges, ring addressing, mask math), plus _RUN_COST per run.
    Unlike the packed objective this is schedule-length-aware on both
    axes: longer programs pay per-tick, fragmented ones per-run, and
    warmup/drain ramps are charged at their own (partitioned) widths
    rather than the steady-state cap.

    The per-tick charge is expressed in lane-slot units.  A lane-slot
    (one vmapped net pass) scales with the batch size while the fixed
    per-tick work does not, so the weight grows as batches shrink —
    calibrated to ~1 lane-slot at the benchmark's B=256 (where it makes
    the cap search trade a 0.98-occupancy width-1 program for a 1.3x
    faster width-2 one; see docs/architecture.md §occupancy)."""
    tick_w = max(1.0, 256.0 / max(batch_size, 1))
    slots = n_runs = t_total = 0
    lo = 0
    for cut, _ in low.cuts[:n_epochs]:
        keep = _live_ticks(low.tb.slice(lo, cut))
        lo = max(lo, cut)
        if not keep:
            continue
        counts = _tick_counts(keep)
        sigs = [tuple(ph for ph in PHASES if tk[ph]) for tk in keep]
        parts = _partition_runs(counts, sigs)
        slots += sum(_run_slots(counts, a, b) for a, b in parts)
        n_runs += len(parts)
        t_total += len(keep)
    return slots + _RUN_COST * n_runs + tick_w * t_total


_SCHEDULE_MEMO: Dict[tuple, CompiledSchedule] = {}
_SCHEDULE_MEMO_CAP = 8


def _memo_key(cfg: RunConfig, events, n_rep_a, n_rep_p, n_samples,
              disable_semi_async, pack) -> tuple:
    # the full event tuple goes into the key (not a digest of it): dict
    # equality then guarantees a hit really is the same log, and the
    # memo holds at most _SCHEDULE_MEMO_CAP entries so the extra memory
    # is bounded
    ev = tuple((t, k, tuple(sorted(pl.items()))) for t, k, pl in events)
    return (ev, cfg.method, cfg.batch_size, cfg.n_epochs,
            cfg.dt0, cfg.seed, cfg.w_a, cfg.w_p, n_rep_a, n_rep_p,
            n_samples, disable_semi_async, pack)


def compile_schedule(cfg: RunConfig, events: List[Tuple], *,
                     n_rep_a: int, n_rep_p: int, n_samples: int,
                     disable_semi_async: bool = False,
                     pack: str = "segmented") -> CompiledSchedule:
    """Lower an event log into a `CompiledSchedule`.

    `pack="dense"` reproduces the legacy one-lane-per-replica layout;
    `pack="packed"` runs a dense pre-pass to estimate the steady-state
    per-phase lane demand, then re-lowers the log under that lane
    budget and emits replica-indexed work rows; `pack="segmented"`
    (default) additionally partitions the packed tick stream into
    phase-signature runs with per-run lane widths for the cond-free
    engine (see module docstring and docs/architecture.md).

    Results are memoized on the log content and config (packed mode runs
    up to 1 + |candidates| host lowerings), so repeat replays of the
    same simulation — sweeps, parity tests, benchmark reps — compile the
    schedule once.  The returned object is shared: treat it as frozen."""
    if pack not in PACKS:
        raise ValueError(f"pack {pack!r} not in {PACKS}")
    memo_key = _memo_key(cfg, events, n_rep_a, n_rep_p, n_samples,
                         disable_semi_async, pack)
    if memo_key in _SCHEDULE_MEMO:
        return _SCHEDULE_MEMO[memo_key]
    rows = _rows_table(cfg, n_samples)
    low = _lower(cfg, events, n_rep_a=n_rep_a, n_rep_p=n_rep_p,
                 disable_semi_async=disable_semi_async)

    if pack in ("packed", "segmented"):
        # pick the lane budget minimizing the modeled execution cost.
        # packed: executed (tick, phase-lane) slots — phases with no
        # active lane in a tick are cond-skipped by the engine — plus
        # one lane-equivalent per tick for fixed scan-step overhead
        # (conds, ring addressing, optimizer bookkeeping).  segmented:
        # the run-partitioned cost (`_segmented_cost`), which charges
        # warmup/drain ramps at their own per-run widths instead of the
        # steady-state cap.  Ties go to the shorter program.
        best = None
        for caps in _cap_candidates(low, n_rep_a, n_rep_p):
            cand = _lower(cfg, events, n_rep_a=n_rep_a, n_rep_p=n_rep_p,
                          disable_semi_async=disable_semi_async, caps=caps)
            T = len(cand.tb.ticks)
            if pack == "segmented":
                cost = (_segmented_cost(cand, cfg.n_epochs,
                                        cfg.batch_size), T)
            else:
                # the packed engine runs both passive sub-phases under
                # one cond, so their widths execute whenever either has
                # work
                passive = sum(1 for tk in cand.tb.ticks
                              if tk["pf"] or tk["pb"])
                active = sum(1 for tk in cand.tb.ticks if tk["as"])
                executed = (caps["pf"] + caps["pb"]) * passive + \
                    caps["as"] * active
                cost = (executed + T, T)
            if best is None or cost < best[0]:
                best = (cost, caps, cand)
        _, caps, low = best
        widths = (caps["pf"], caps["pb"], caps["as"])
    else:
        widths = (n_rep_p, n_rep_p, n_rep_a)

    segments, lo = [], 0
    for cut, epoch_agg in low.cuts[:cfg.n_epochs]:
        ticks = low.tb.slice(lo, cut)
        if pack == "segmented":
            segments.append(_materialize_segmented(ticks, epoch_agg))
        elif pack == "packed":
            segments.append(_materialize_packed(ticks, widths, epoch_agg))
        else:
            segments.append(_materialize_dense(ticks, n_rep_a, n_rep_p,
                                               epoch_agg))
        lo = max(lo, cut)

    sched = CompiledSchedule(
        method=cfg.method, n_rep_a=n_rep_a, n_rep_p=n_rep_p,
        n_epochs=cfg.n_epochs, rows=rows, segments=segments,
        emb_slots=low.emb_slots, grad_slots=low.grad_slots,
        staleness=low.staleness, n_updates=low.n_updates,
        has_inscan_agg=low.has_inscan, versions_p=low.versions_p,
        pack=pack, lane_widths=widths,
        epoch_live=tuple(low.epoch_live), final_live=low.final_live,
        rejoins=tuple(low.rejoins))
    if len(_SCHEDULE_MEMO) >= _SCHEDULE_MEMO_CAP:
        _SCHEDULE_MEMO.pop(next(iter(_SCHEDULE_MEMO)))
    _SCHEDULE_MEMO[memo_key] = sched
    return sched


# ---------------------------------------------------------------------------
# device-aware lowering: slab-balanced lane permutation + masked padding
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SlabPlan:
    """How one party's replica axis lays over a 1-D device mesh.

    Lanes are grouped into contiguous per-device **slabs** of
    `lanes_per_device` so a NamedSharding over the leading axis gives each
    device whole lanes.  Real replicas fill the slabs round-balanced
    (device d holds `n_real // n_devices + (1 if d < n_real % n_devices)`
    real lanes, so loads differ by at most one); the remaining lanes are
    **padding**: they carry replica-0's initial params, are never named by
    any `*_rep` work row, and therefore never execute an op — masked out
    exactly like an empty packed lane.  `lane_of[r]` is the lane of real
    replica r; `rep_of[l]` inverts it (-1 = padding)."""
    n_real: int
    n_devices: int
    lanes_per_device: int
    lane_of: Tuple[int, ...]
    rep_of: Tuple[int, ...]

    @property
    def n_lanes(self) -> int:
        return self.n_devices * self.lanes_per_device

    @property
    def is_identity(self) -> bool:
        return self.n_lanes == self.n_real and \
            self.lane_of == tuple(range(self.n_real))

    @property
    def device_load(self) -> Tuple[int, ...]:
        """Real lanes per device (balanced within 1 by construction)."""
        P = self.lanes_per_device
        return tuple(sum(1 for r in self.rep_of[d * P:(d + 1) * P]
                         if r >= 0) for d in range(self.n_devices))


def slab_plan(n_real: int, n_devices: int) -> SlabPlan:
    """Balanced lane assignment of `n_real` replicas over `n_devices`.

    A multi-device plan always keeps at least one padding lane: when the
    replica count divides the device count evenly, the slab width is
    bumped by one.  This is a numerical requirement, not a convenience —
    with every lane populated, the per-tick phase gathers cover the whole
    lane axis and the partitioner shards the phase compute across
    devices, contracting FMAs differently from the single-device program
    (~ULP-level divergence that Adam then amplifies).  With the gather a
    proper subset of the lanes, the partitioner materializes the gathered
    stack replicated and the phase compute is the exact single-device
    kernel, which is what the engine's bit-parity contract relies on."""
    if n_real < 1 or n_devices < 1:
        raise ValueError(f"need n_real >= 1, n_devices >= 1; got "
                         f"({n_real}, {n_devices})")
    per = -(-n_real // n_devices)            # ceil
    if n_devices > 1 and n_real % n_devices == 0:
        per += 1                             # force >= 1 padding lane
    lane_of: List[int] = []
    rep_of = [-1] * (n_devices * per)
    r = 0
    for d in range(n_devices):
        load = n_real // n_devices + (1 if d < n_real % n_devices else 0)
        for j in range(load):
            lane = d * per + j
            lane_of.append(lane)
            rep_of[lane] = r
            r += 1
    return SlabPlan(n_real=n_real, n_devices=n_devices,
                    lanes_per_device=per, lane_of=tuple(lane_of),
                    rep_of=tuple(rep_of))


def _remap_rep(arr: np.ndarray, plan: SlabPlan) -> np.ndarray:
    """Rewrite a `*_rep` work-row array from replica to lane indices.
    Empty lanes (-1) stay empty; within-tick lane positions are NOT
    re-sorted, so decode order and scatter conflict-freedom (each replica
    at most once per phase per tick, preserved by injectivity of
    `lane_of`) carry over unchanged."""
    m = np.asarray(plan.lane_of, np.int32)
    return np.where(arr >= 0, m[np.maximum(arr, 0)], np.int32(-1))


def device_lower(sched: CompiledSchedule,
                 n_devices: int) -> CompiledSchedule:
    """Lower a compiled schedule for an `n_devices`-way replica mesh.

    Returns a derived copy (the memoized input is shared and treated as
    frozen) whose `*_rep` arrays name **lanes** under the two slab plans
    and whose `n_rep_a`/`n_rep_p` are the padded lane counts.  Slot, bid
    and agg arrays are untouched — ring-slot lifetimes are lane-layout
    invariant.  A lowered schedule always carries padding lanes (see
    `slab_plan` — a fully-populated lane axis breaks bit parity), so the
    lane map is never the identity and the lowered runner is a distinct
    cache entry from the single-device one.  Dense layouts are rejected:
    their DP noise draw is shaped by the replica count, so padding would
    change the noise stream and break bit parity."""
    if n_devices <= 1:
        return sched
    if sched.pack not in ("packed", "segmented"):
        raise ValueError(
            f"mesh replay requires pack in ('packed', 'segmented'); "
            f"pack={sched.pack!r} draws per-replica DP noise and cannot "
            f"be padded without changing the noise stream")
    plan_a = slab_plan(sched.n_rep_a, n_devices)
    plan_p = slab_plan(sched.n_rep_p, n_devices)

    def remap_packed(seg: PackedSegment) -> PackedSegment:
        return replace(seg,
                       pf_rep=_remap_rep(seg.pf_rep, plan_p),
                       pb_rep=_remap_rep(seg.pb_rep, plan_p),
                       as_rep=_remap_rep(seg.as_rep, plan_a))

    def remap_run(run: Run) -> Run:
        arrays = dict(run.arrays)
        for ph, plan in (("pf", plan_p), ("pb", plan_p), ("as", plan_a)):
            if ph in run.sig:
                arrays[f"{ph}_rep"] = _remap_rep(arrays[f"{ph}_rep"], plan)
        return Run(sig=run.sig, has_agg=run.has_agg, arrays=arrays)

    if sched.pack == "segmented":
        segments: List[Union[Segment, PackedSegment, SegmentedSegment]] = [
            SegmentedSegment(runs=[remap_run(r) for r in s.runs],
                             epoch_agg=s.epoch_agg)
            for s in sched.segments]
    else:
        segments = [remap_packed(s) for s in sched.segments]
    return replace(sched, n_rep_a=plan_a.n_lanes, n_rep_p=plan_p.n_lanes,
                   segments=segments, slab_a=plan_a, slab_p=plan_p)
