"""Discrete-event runtimes for PubSub-VFL and the four baselines.

Methods (paper §5.1 baselines + ours):
  vfl      — pure two-party split learning, one worker pair, fully serial
  vfl_ps   — PS data parallelism, strict ID-aligned pairing, per-round barrier
  avfl     — asynchronous P2P pairing (1-deep pipeline), no PS
  avfl_ps  — avfl + per-epoch PS aggregation of worker replicas
  pubsub   — PubSub-VFL: per-batch channels (buffers p/q, FIFO eviction),
             waiting deadline T_ddl, pooled (decoupled) worker matching and
             intra-party semi-async PS on the Eq. 5 schedule

The engine produces (a) system metrics — simulated wall time, CPU
utilization, waiting/epoch, comm MB — and (b) an event log in completion
order that `core.trainer` replays with real JAX updates, so learning
dynamics (staleness included) are real, only *time* is modeled (DESIGN §3).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import CostModel, SystemProfile
from repro.core.faults import FaultPlan
from repro.core.semi_async import delta_t
from repro.core.sim import Engine, Store

METHODS = ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub")


@dataclass
class RunConfig:
    method: str
    n_samples: int
    batch_size: int
    n_epochs: int
    w_a: int
    w_p: int
    profile: SystemProfile
    p: int = 5
    q: int = 5
    t_ddl: float = 10.0
    dt0: int = 5
    jitter: float = 0.10          # lognormal per-task compute jitter
    seed: int = 0
    agg_overhead: float = 0.02    # PS aggregate+broadcast (intra-party)
    faults: Optional[FaultPlan] = None   # deterministic failure scenario

    @property
    def n_batches(self) -> int:
        return max(self.n_samples // self.batch_size, 1)


@dataclass
class SimResult:
    method: str
    total_time: float
    cpu_util: float
    waiting_per_epoch: float
    comm_mb: float
    events: List[Tuple]           # (t, kind, payload)
    stats: Dict = field(default_factory=dict)


class Barrier:
    def __init__(self, engine: Engine, n: int):
        self.engine, self.n = engine, n
        self.waiting: List = []

    def arrive(self):
        """Yieldable: blocks until n processes arrive."""
        store = Store(self.engine)
        self.waiting.append(store)
        if len(self.waiting) == self.n:
            for s in self.waiting:
                s.put(True)
            self.waiting = []
        return store


def _speeds(rng, n, jitter):
    return np.exp(rng.normal(0.0, jitter, size=n))


def simulate(cfg: RunConfig) -> SimResult:
    if cfg.method not in METHODS:
        raise ValueError(f"method {cfg.method!r} not in {METHODS}")
    eng = Engine()
    cm = CostModel(cfg.profile)
    rng = np.random.default_rng(cfg.seed)
    B = cfg.batch_size
    w_a, w_p = cfg.w_a, cfg.w_p
    if cfg.method == "vfl":
        w_a = w_p = 1
    if cfg.method in ("vfl_ps", "avfl", "avfl_ps"):
        # strict ID alignment forces 1:1 pairing (Appendix A)
        w_a = w_p = min(w_a, w_p)

    t_fp = cm.t_f_p(B, w_p)
    t_bp = cm.t_b_p(B, w_p)
    t_a = cm.t_f_a(B, w_a) + cm.t_top_a(B, w_a) + cm.t_b_a(B, w_a)
    t_emb, t_grad = cm.t_emb(B), cm.t_grad(B)
    emb_mb = cfg.profile.emb_bytes_per_sample * B / 1e6
    grad_mb = cfg.profile.grad_bytes_per_sample * B / 1e6

    speed_p = _speeds(rng, w_p, cfg.jitter)
    speed_a = _speeds(rng, w_a, cfg.jitter)
    # t_ddl <= 0 or inf disables the waiting-deadline mechanism (the
    # "w/o T_all" ablation): subscribers block forever instead of dropping
    no_ddl = (cfg.t_ddl <= 0) or math.isinf(cfg.t_ddl)

    def recv(store):
        if no_ddl:
            return ("get", store)
        return ("get_timeout", store, cfg.t_ddl)

    # ---- fault injection (core.faults): everything below is driven by
    # the declarative FaultPlan so faults land in the event log at
    # deterministic times under the run seed.
    fp = cfg.faults if (cfg.faults is not None
                        and not cfg.faults.empty) else None
    if fp is not None:
        fp.validate(cfg.method)
        if fp.drops and no_ddl:
            raise ValueError(
                "channel-drop faults require a finite t_ddl (dropped "
                "messages are absorbed by the waiting-deadline machinery; "
                "without it subscribers block forever)")
    fstats = {"crashes": 0, "rejoins": 0, "stalls": 0, "chan_dropped": 0,
              "rejoin_staleness": []}
    _fired: set = set()

    def rate(side: str, j: int) -> float:
        """Time-varying straggler slowdown (exactly 1.0 when healthy)."""
        return 1.0 if fp is None else fp.multiplier(side, j, eng.now)

    def _next_crash(side: str, j: int):
        for c in (fp.crashes_for(side, j) if fp is not None else ()):
            if c not in _fired and eng.now >= c.at:
                return c
        return None

    def _outage(side: str, j: int):
        """Pubsub fail-stop window, entered at the worker's next
        scheduling point after the configured time.  The worker emits no
        events during the outage (its lanes go dark in the lowering);
        returns True for a permanent crash — the caller exits and the
        shared job queue lets survivors absorb its work."""
        while True:
            c = _next_crash(side, j)
            if c is None:
                return False
            _fired.add(c)
            fstats["crashes"] += 1
            if math.isinf(c.rejoin_after):
                eng.log("crash", w=j, side=side, final=True)
                return True
            eng.log("crash", w=j, side=side, final=False)
            till = c.at + c.rejoin_after
            if till > eng.now:
                yield ("sleep", till - eng.now)
            stale = float(eng.now - c.at)
            fstats["rejoins"] += 1
            fstats["rejoin_staleness"].append(stale)
            eng.log("rejoin", w=j, side=side, stale=stale)

    def _stall(side: str, k: int):
        """Paired-method crash = stall: the strict pairing has no pool
        to absorb a fail-stop, so the worker just goes unavailable and
        every barrier partner waits (work conserved, wall time pays)."""
        while True:
            c = _next_crash(side, k)
            if c is None:
                return
            _fired.add(c)
            fstats["stalls"] += 1
            eng.log("stall", w=k, side=side)
            till = c.at + c.rejoin_after
            if till > eng.now:
                yield ("sleep", till - eng.now)
            eng.log("resume", w=k, side=side)

    busy = {"a": 0.0, "p": 0.0}
    wait = {"a": 0.0, "p": 0.0}
    comm = {"mb": 0.0, "msgs": 0}
    drops = {"deadline": 0, "evicted": 0}

    def deliver(store: Store, item, delay: float, mb: float):
        comm["mb"] += mb
        comm["msgs"] += 1

        def _put():
            store.put(item)
            return
            yield  # pragma: no cover

        eng._push(eng.now + delay, ("resume", _put()), None)

    # ---------------------------------------------------------------- pubsub
    if cfg.method == "pubsub":
        # pooled embedding channel (union of per-batch channels; capacity
        # p per passive worker) and per-batch gradient delivery
        emb_pool = Store(eng, capacity=cfg.p * w_p)
        grad_stores = [Store(eng) for _ in range(w_p)]
        job_queue: deque = deque()
        ctr = {"published": 0, "consumed": 0}
        live = {"p": w_p}                 # passive workers not failed-stop
        sync_marks = _pubsub_sync_epochs(cfg)

        if fp is not None and fp.drops:
            # lose messages in transit: every drop_every-th arrival in a
            # burst window never reaches the channel (sim.Store counts it
            # in n_dropped; the deadline machinery absorbs the loss like
            # an eviction)
            chan_ctr = {"emb": 0, "grad": 0}

            def _drop_filter(chan):
                bursts = tuple(d for d in fp.drops if d.channel == chan)

                def f(item):
                    for d in bursts:
                        if d.start <= eng.now < d.start + d.duration:
                            chan_ctr[chan] += 1
                            if chan_ctr[chan] % d.drop_every == 0:
                                fstats["chan_dropped"] += 1
                                eng.log("chan_drop", chan=chan)
                                return True
                            return False
                    return False
                return f

            emb_pool.drop_filter = _drop_filter("emb")
            _grad_filter = _drop_filter("grad")
            for _gs in grad_stores:
                _gs.drop_filter = _grad_filter

        def passive_worker(j):
            inflight = 0
            while True:
                if fp is not None and (yield from _outage("p", j)):
                    live["p"] -= 1
                    return              # fail-stop: pool absorbs the jobs
                ok, g = grad_stores[j].try_get()
                if ok:
                    dt = t_bp * speed_p[j] * rate("p", j)
                    yield ("sleep", dt)
                    busy["p"] += dt
                    eng.log("p_bwd", w=j, bid=g)
                    inflight -= 1
                    continue
                if job_queue and inflight < cfg.p:
                    bid, ep = job_queue.popleft()
                    dt = t_fp * speed_p[j] * rate("p", j)
                    yield ("sleep", dt)
                    busy["p"] += dt
                    eng.log("p_fwd", w=j, bid=bid, ep=ep)
                    ctr["published"] += 1
                    deliver(emb_pool, (bid, j, ep), t_emb, emb_mb)
                    inflight += 1
                    continue
                if inflight == 0 and not job_queue:
                    return
                t0 = eng.now
                g = yield recv(grad_stores[j])
                wait["p"] += eng.now - t0
                if g is None:
                    drops["deadline"] += 1
                    eng.log("drop", w=j, side="p")
                    inflight = max(inflight - 1, 0)
                    continue
                dt = t_bp * speed_p[j] * rate("p", j)
                yield ("sleep", dt)
                busy["p"] += dt
                eng.log("p_bwd", w=j, bid=g)
                inflight -= 1

        def active_worker(i):
            while True:
                if fp is not None and (yield from _outage("a", i)):
                    return              # fail-stop: pool absorbs the load
                t0 = eng.now
                msg = yield recv(emb_pool)
                if msg is None:
                    # in-transit channel drops are subtracted like
                    # evictions; a dead passive party (live == 0) can
                    # never publish again, so stop once the pool drains
                    outstanding = (ctr["published"] - ctr["consumed"]
                                   - emb_pool.n_evicted
                                   - emb_pool.n_dropped)
                    if (not job_queue or live["p"] == 0) \
                            and outstanding <= 0:
                        return          # terminal wait: not starvation
                    wait["a"] += eng.now - t0
                    drops["deadline"] += 1
                    eng.log("drop", w=i, side="a")
                    continue
                wait["a"] += eng.now - t0
                bid, j, ep = msg
                ctr["consumed"] += 1
                dt = t_a * speed_a[i] * rate("a", i)
                yield ("sleep", dt)
                busy["a"] += dt
                eng.log("a_step", w=i, bid=bid, ep=ep)
                deliver(grad_stores[j], bid, t_grad, grad_mb)

        # all work is enqueued up front (the broker decouples production
        # from consumption; epoch identity travels with each job).  PS
        # aggregation points (Eq. 5 schedule) are replayed by the trainer
        # from completed-step counts, not simulated as barriers — that is
        # exactly the semi-asynchronous semantics.
        for ep in range(cfg.n_epochs):
            for b in range(cfg.n_batches):
                job_queue.append((ep * cfg.n_batches + b, ep))
        for j in range(w_p):
            eng.process(passive_worker(j))
        for i in range(w_a):
            eng.process(active_worker(i))
        eng.run()
        drops["evicted"] = emb_pool.n_evicted
        del sync_marks  # schedule consumed by the trainer, not the DES

    # ------------------------------------------------------- paired methods
    else:
        # pipeline depth: sync methods and AVFL's blocking P2P handshake
        # admit no overlap (the passive worker cannot start batch b+1 until
        # batch b's gradient lands); AVFL-PS's replica decoupling gives a
        # 1-deep overlap (Table 5/10 of the paper: AVFL has the worst
        # waiting/utilization, AVFL-PS recovers most of it)
        pipeline = 2 if cfg.method == "avfl_ps" else 1
        per_round_barrier = cfg.method in ("vfl", "vfl_ps")
        per_epoch_barrier = cfg.method == "avfl_ps"    # PS epoch aggregation
        # never spawn more pairs than there are batches per epoch
        n_pairs = max(1, min(w_a, cfg.n_batches))
        w_a = w_p = n_pairs
        # per-(epoch, round) barriers sized by the pairs actually holding a
        # batch in that round (the final round of an epoch may be partial)
        full_rounds = cfg.n_batches // n_pairs
        rem = cfg.n_batches % n_pairs
        round_barriers: Dict[Tuple[int, int], Barrier] = {}
        epoch_barriers: Dict[int, Barrier] = {}

        def round_barrier(ep: int, rnd: int) -> Barrier:
            key = (ep, rnd)
            if key not in round_barriers:
                n = n_pairs if rnd < full_rounds else rem
                round_barriers[key] = Barrier(eng, 2 * n)
            return round_barriers[key]

        def round_of(bid: int) -> Tuple[int, int]:
            ep = bid // cfg.n_batches
            return ep, (bid % cfg.n_batches) // n_pairs

        def epoch_barrier(ep: int) -> Barrier:
            if ep not in epoch_barriers:
                epoch_barriers[ep] = Barrier(eng, 2 * n_pairs)
            return epoch_barriers[ep]

        emb_stores = [Store(eng) for _ in range(n_pairs)]
        grad_stores = [Store(eng) for _ in range(n_pairs)]

        def quota_pe(k: int) -> int:
            return full_rounds + (1 if k < rem else 0)

        def pair_passive(k, batches):
            inflight = 0
            done_in_epoch: Dict[int, int] = {}
            todo = deque(batches)

            def after_bwd(g):
                ep = g // cfg.n_batches
                done_in_epoch[ep] = done_in_epoch.get(ep, 0) + 1
                need_round = per_round_barrier
                need_epoch = (per_epoch_barrier and
                              done_in_epoch[ep] == quota_pe(k))
                return need_round, need_epoch, ep

            while todo or inflight:
                if fp is not None:
                    yield from _stall("p", k)
                ok, g = grad_stores[k].try_get()
                if not ok and todo and inflight < pipeline:
                    bid, ep = todo.popleft()
                    dt = t_fp * speed_p[k] * rate("p", k)
                    yield ("sleep", dt)
                    busy["p"] += dt
                    eng.log("p_fwd", w=k, bid=bid, ep=ep)
                    deliver(emb_stores[k], (bid, ep), t_emb, emb_mb)
                    inflight += 1
                    continue
                if not ok:
                    t0 = eng.now
                    g = yield ("get", grad_stores[k])
                    wait["p"] += eng.now - t0
                dt = t_bp * speed_p[k] * rate("p", k)
                yield ("sleep", dt)
                busy["p"] += dt
                eng.log("p_bwd", w=k, bid=g)
                inflight -= 1
                need_round, need_epoch, ep = after_bwd(g)
                if need_round:
                    st = round_barrier(*round_of(g)).arrive()
                    t0 = eng.now
                    yield ("get", st)
                    wait["p"] += eng.now - t0
                if need_epoch:
                    st = epoch_barrier(ep).arrive()
                    t0 = eng.now
                    yield ("get", st)
                    wait["p"] += eng.now - t0

        def pair_active(k, batches):
            done_in_epoch: Dict[int, int] = {}
            for _ in range(len(batches)):
                if fp is not None:
                    yield from _stall("a", k)
                t0 = eng.now
                msg = yield ("get", emb_stores[k])
                wait["a"] += eng.now - t0
                bid, ep = msg
                dt = t_a * speed_a[k] * rate("a", k)
                yield ("sleep", dt)
                busy["a"] += dt
                eng.log("a_step", w=k, bid=bid, ep=ep)
                deliver(grad_stores[k], bid, t_grad, grad_mb)
                done_in_epoch[ep] = done_in_epoch.get(ep, 0) + 1
                if per_round_barrier:
                    st = round_barrier(*round_of(bid)).arrive()
                    t0 = eng.now
                    yield ("get", st)
                    wait["a"] += eng.now - t0
                if per_epoch_barrier and done_in_epoch[ep] == quota_pe(k):
                    st = epoch_barrier(ep).arrive()
                    t0 = eng.now
                    yield ("get", st)
                    wait["a"] += eng.now - t0

        # assign batches round-robin to pairs, epoch by epoch; the final
        # round of an epoch may be partial (its barrier is sized to the
        # participating pairs).
        assignments: List[List] = [[] for _ in range(n_pairs)]
        for ep in range(cfg.n_epochs):
            for b in range(cfg.n_batches):
                assignments[b % n_pairs].append((ep * cfg.n_batches + b, ep))

        for k in range(n_pairs):
            eng.process(pair_passive(k, assignments[k]))
            eng.process(pair_active(k, assignments[k]))
        eng.run()

    # total time = last completed unit of real work (not the deadline tail
    # active workers spend noticing the run is over)
    work = [t for t, kind, _ in eng.trace
            if kind in ("p_fwd", "a_step", "p_bwd")]
    total_time = max(work) if work else eng.now
    C_a = cfg.profile.active.cores
    C_p = cfg.profile.passive.cores
    core_seconds = busy["a"] * (C_a / w_a) + busy["p"] * (C_p / w_p)
    util = core_seconds / max(total_time * (C_a + C_p), 1e-9)
    waiting = (wait["a"] + wait["p"]) / max(cfg.n_epochs, 1)
    events = sorted(eng.trace, key=lambda e: e[0])
    return SimResult(
        method=cfg.method, total_time=total_time, cpu_util=util,
        waiting_per_epoch=waiting, comm_mb=comm["mb"], events=events,
        stats={"drops": drops, "msgs": comm["msgs"],
               "busy_a": busy["a"], "busy_p": busy["p"],
               "wait_a": wait["a"], "wait_p": wait["p"],
               "w_a": w_a, "w_p": w_p, "faults": fstats},
    )


def _pubsub_sync_epochs(cfg: RunConfig) -> set:
    marks, t = set(), 0
    while t < cfg.n_epochs:
        t += delta_t(t, cfg.dt0)
        marks.add(t)
    return marks


