"""Embedding Inversion Attack (paper Appendix G, following [49]).

The adversary holds a shadow dataset of (embedding, passive-features)
pairs and fits an inversion model mapping published embeddings back to
raw features.  We use the closed-form ridge inverter (the strongest linear
attacker); ASR = fraction of test samples whose reconstruction correlation
exceeds a threshold.
"""
from __future__ import annotations

import numpy as np


def fit_inverter(z_shadow: np.ndarray, x_shadow: np.ndarray,
                 reg: float = 1e-3) -> np.ndarray:
    """Ridge: W = (Z^T Z + reg I)^-1 Z^T X."""
    d = z_shadow.shape[1]
    A = z_shadow.T @ z_shadow + reg * np.eye(d)
    return np.linalg.solve(A, z_shadow.T @ x_shadow)


def attack_success_rate(z_victim: np.ndarray, x_victim: np.ndarray,
                        W: np.ndarray, threshold: float = 0.8) -> float:
    """Per-sample Pearson correlation of reconstruction vs truth."""
    x_hat = z_victim @ W
    xc = x_victim - x_victim.mean(axis=1, keepdims=True)
    hc = x_hat - x_hat.mean(axis=1, keepdims=True)
    denom = (np.linalg.norm(xc, axis=1) * np.linalg.norm(hc, axis=1))
    corr = (xc * hc).sum(axis=1) / np.maximum(denom, 1e-12)
    return float((corr > threshold).mean())


def run_eia(passive_forward, theta_p, X_p: np.ndarray, *, sigma: float,
            clip: float, seed: int = 0, shadow_frac: float = 0.5,
            threshold: float = 0.8) -> float:
    """End-to-end EIA against a trained passive bottom model with the GDP
    mechanism applied to published embeddings."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    z = np.asarray(passive_forward(theta_p, jnp.asarray(X_p)))
    nrm = np.linalg.norm(z, axis=-1, keepdims=True)
    z = z * np.minimum(1.0, clip / np.maximum(nrm, 1e-12))
    if sigma > 0:
        z = z + sigma * rng.normal(size=z.shape).astype(z.dtype)
    n = len(z)
    k = int(n * shadow_frac)
    idx = rng.permutation(n)
    sh, vi = idx[:k], idx[k:]
    W = fit_inverter(z[sh], X_p[sh])
    return attack_success_rate(z[vi], X_p[vi], W, threshold)
