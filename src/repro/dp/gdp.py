"""Gaussian Differential Privacy for cut-layer embeddings (paper Appendix C).

sigma_dp = N_m * sqrt(K) / (mu * N)        (Eq. 17)

where N_m = worker minibatch size, N = global batch size, K = number of
queries (batches processed per worker), mu = GDP privacy parameter.
`mu = inf` disables noise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class GDPConfig:
    mu: float = math.inf      # privacy loss parameter (smaller = stronger)
    clip: float = 1.0         # L2 sensitivity bound on embeddings
    minibatch: int = 32       # N_m
    global_batch: int = 256   # N
    n_queries: int = 1000     # K


def noise_sigma(cfg: GDPConfig) -> float:
    if not math.isfinite(cfg.mu) or cfg.mu <= 0:
        return 0.0
    return cfg.minibatch * math.sqrt(cfg.n_queries) / (cfg.mu *
                                                       cfg.global_batch)


def compose_mu(mus) -> float:
    """GDP composition: mu_total = sqrt(sum mu_i^2) (Dong et al. 2019)."""
    return math.sqrt(sum(m * m for m in mus))


def mu_to_epsilon_delta(mu: float, delta: float = 1e-5) -> float:
    """Convert mu-GDP to (eps, delta)-DP via the dual formula (numeric)."""
    from math import erf, exp, log, sqrt

    def Phi(x):
        return 0.5 * (1 + erf(x / sqrt(2)))

    # delta(eps) = Phi(-eps/mu + mu/2) - e^eps Phi(-eps/mu - mu/2)
    lo, hi = 0.0, 100.0
    for _ in range(200):
        eps = 0.5 * (lo + hi)
        d = Phi(-eps / mu + mu / 2) - exp(eps) * Phi(-eps / mu - mu / 2)
        if d > delta:
            lo = eps
        else:
            hi = eps
    return 0.5 * (lo + hi)


def add_noise(rng: np.ndarray, emb: np.ndarray, cfg: GDPConfig) -> np.ndarray:
    """Numpy-side GDP mechanism (the jitted path uses kernels.cut_layer)."""
    sigma = noise_sigma(cfg)
    norm = np.linalg.norm(emb, axis=-1, keepdims=True)
    emb = emb * np.minimum(1.0, cfg.clip / np.maximum(norm, 1e-12))
    if sigma > 0:
        emb = emb + sigma * rng.normal(size=emb.shape).astype(emb.dtype)
    return emb
