"""Pallas TPU kernel for the RWKV6 wkv recurrence.

TPU adaptation (DESIGN.md §4): one (batch, head) pair per major grid step;
the D x D fp32 state stays resident in VMEM scratch while time is streamed
through in chunks of ``block_t`` along the minor (sequential) grid axis.
The inner chunk loop is a fori_loop over single steps — the recurrence is
inherently sequential in t, but all D x D work per step is vectorized on
the VPU and the state never round-trips to HBM between chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  y_ref, sout_ref, state, *, block_t: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    def step(t, S):
        r_t = r_ref[0, t].astype(jnp.float32)          # (D,)
        k_t = k_ref[0, t].astype(jnp.float32)
        v_t = v_ref[0, t].astype(jnp.float32)
        w_t = w_ref[0, t].astype(jnp.float32)
        u = u_ref[0].astype(jnp.float32)               # (D,)
        a = k_t[:, None] * v_t[None, :]                # (D,D)
        y = jnp.sum((S + u[:, None] * a) * r_t[:, None], axis=0)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return w_t[:, None] * S + a

    S = jax.lax.fori_loop(0, block_t, step, state[...])
    state[...] = S

    @pl.when(c == n_chunks - 1)
    def _finish():
        sout_ref[0] = S


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan_pallas(r, k, v, w, u, state, *, block_t: int = 128,
                      interpret: bool = True):
    """r,k,v,w: (B,S,H,D); u: (H,D); state: (B,H,D,D) fp32."""
    B, S, H, D = r.shape
    block_t = min(block_t, S)
    assert S % block_t == 0, (S, block_t)
    n_chunks = S // block_t
    # (B*H, S, D) layout: one row of the major grid per (b,h)
    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    rr, kk, vv, ww = bh(r), bh(k), bh(v), bh(w)
    uu = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, D)
    s0 = state.reshape(B * H, D, D).astype(jnp.float32)

    t_spec = pl.BlockSpec((1, block_t, D), lambda i, c: (i, c, 0))
    y, s_out = pl.pallas_call(
        functools.partial(_rwkv6_kernel, block_t=block_t, n_chunks=n_chunks),
        grid=(B * H, n_chunks),
        in_specs=[
            t_spec, t_spec, t_spec, t_spec,
            pl.BlockSpec((1, D), lambda i, c: (i, 0)),
            pl.BlockSpec((1, D, D), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            t_spec,
            pl.BlockSpec((1, D, D), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, D), r.dtype),
            jax.ShapeDtypeStruct((B * H, D, D), jnp.float32),
        ],
        # fp32 running state, VMEM-resident across time chunks
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)
    y = y.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    return y, s_out.reshape(B, H, D, D)
