"""Pure-jnp oracle for the RWKV6 (Finch) wkv recurrence.

Per head (state S in R^{D x D}, row index = key dim, col index = value dim):
    y_t = sum_i r_t[i] * (S_{t-1}[i,:] + u[i] * k_t[i] * v_t[:])
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, state):
    """r,k,v,w: (B,S,H,D); u: (H,D); state: (B,H,D,D) fp32.

    Returns (y: (B,S,H,D) in r.dtype, new_state: (B,H,D,D) fp32)."""
    dtype = r.dtype
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,D) each
        a = k_t[..., :, None] * v_t[..., None, :]      # (B,H,D,D)
        y = jnp.sum((S + uf[None, :, :, None] * a) * r_t[..., :, None],
                    axis=-2)                            # (B,H,D)
        S_new = w_t[..., :, None] * S + a
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(dtype), state
