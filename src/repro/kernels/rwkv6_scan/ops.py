"""Public jit'd wrapper for the RWKV6 wkv scan."""
from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas


def rwkv6_scan(r, k, v, w, u, state, *, use_pallas: bool = False,
               block_t: int = 128):
    """Dispatch: Pallas kernel (TPU target / interpret on CPU) or jnp oracle.

    The jnp path is the default inside jitted model code (the XLA dry-run
    cannot lower Mosaic on the host platform); kernel correctness is pinned
    to the oracle by tests/test_kernels.py sweeps.
    """
    if use_pallas:
        S = r.shape[1]
        bt = block_t
        while S % bt:
            bt //= 2
        return rwkv6_scan_pallas(r, k, v, w, u, state, block_t=max(bt, 1),
                                 interpret=default_interpret())
    return rwkv6_scan_ref(r, k, v, w, u, state)
