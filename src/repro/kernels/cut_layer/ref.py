"""Pure-jnp oracle for the fused cut-layer op.

The cut layer is the trust boundary of PubSub-VFL: the passive party's
embedding is projected, squashed, L2-clipped (DP sensitivity bound) and
Gaussian-DP noised before it is published to the embedding channel
(paper §4.1 + Appendix C).  Fusing these avoids materializing the
pre-noise embedding in HBM.
"""
from __future__ import annotations

import jax.numpy as jnp


def cut_layer_ref(x, w, b, noise, *, clip: float, sigma: float,
                  residual=None):
    """x: (M,K); w: (K,N); b: (N,); noise: (M,N) standard normal;
    residual: optional (M,N) skip input added after the tanh (the
    "large model" residual bottom variant, where the cut layer keeps
    its block's skip connection).

    y = tanh(x @ w + b) [+ residual];
    y *= min(1, clip/||y||2) rowwise;  y += sigma*noise
    """
    y = jnp.tanh(x.astype(jnp.float32) @ w.astype(jnp.float32)
                 + b.astype(jnp.float32))
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    norm = jnp.linalg.norm(y, axis=-1, keepdims=True)
    y = y * jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
    y = y + sigma * noise.astype(jnp.float32)
    return y.astype(x.dtype)
