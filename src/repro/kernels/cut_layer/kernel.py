"""Pallas TPU kernel: fused cut-layer projection + tanh + L2 clip + DP noise.

TPU adaptation: grid (m_blocks, k_blocks); K is streamed on the minor
sequential axis into an fp32 (block_m, N) VMEM accumulator (the full
embedding row must be resident for the row-wise L2 clip, and cut-layer
widths — the model's d_model, <= 5120 here — fit VMEM comfortably).  The
epilogue (bias, tanh, clip, noise) runs once on the last k step, so the
pre-noise embedding never exists in HBM: what leaves the kernel is already
differentially private.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cut_layer_kernel(*refs, n_k: int, with_residual: bool):
    if with_residual:
        x_ref, w_ref, b_ref, n_ref, r_ref, cs_ref, o_ref, acc = refs
    else:
        x_ref, w_ref, b_ref, n_ref, cs_ref, o_ref, acc = refs
        r_ref = None
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                        w_ref[...].astype(jnp.float32))

    @pl.when(kj == n_k - 1)
    def _epilogue():
        # clip/sigma arrive as an SMEM scalar pair so the compiled kernel
        # is reused across DP settings (a Session sweep varies dp_mu with
        # one XLA program; see api/session.py)
        clip = cs_ref[0, 0]
        sigma = cs_ref[0, 1]
        y = jnp.tanh(acc[...] + b_ref[...].astype(jnp.float32))
        if r_ref is not None:           # residual enters BEFORE the clip
            y = y + r_ref[...].astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(y * y, axis=-1, keepdims=True))
        y = y * jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-12))
        y = y + sigma * n_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


def _clamp_block(dim: int, block: int) -> int:
    """Largest divisor of `dim` that is <= `block` (so non-multiple batch
    sizes never trip the grid arithmetic)."""
    block = min(block, dim)
    while dim % block:
        block -= 1
    return max(block, 1)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k",
                                             "interpret"))
def cut_layer_pallas(x, w, b, noise, residual=None, *, clip,
                     sigma, block_m: int = 128, block_k: int = 512,
                     interpret: bool = None):
    """interpret=None auto-selects: compiled on TPU, interpreter off-TPU
    (Mosaic does not lower on host platforms); REPRO_PALLAS_INTERPRET
    overrides either way.

    `clip` and `sigma` are *runtime* scalars (Python floats or traced
    f32 scalars): they ride into the kernel as one (1, 2) SMEM pair, so
    a compiled kernel is reused across DP settings instead of
    specializing per (clip, sigma).

    `residual` (optional, (M, N)) is the skip input of the residual
    ("large model") bottom variant: added to the tanh output in the
    epilogue, before the L2 clip, so the fused publish still never
    materializes a pre-noise embedding in HBM.  It rides the same
    (block_m, N) blocking as the noise — the full embedding row is
    already VMEM-resident for the row-wise clip."""
    if interpret is None:
        from repro.kernels import default_interpret
        interpret = default_interpret()
    M, K = x.shape
    N = w.shape[1]
    block_m = _clamp_block(M, block_m)
    block_k = _clamp_block(K, block_k)
    n_k = K // block_k
    row_spec = pl.BlockSpec((block_m, N), lambda i, j: (i, 0))
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j: (i, j)),
        pl.BlockSpec((block_k, N), lambda i, j: (j, 0)),
        pl.BlockSpec((N,), lambda i, j: (0,)),
        row_spec,
    ]
    args = (x, w, b, noise)
    if residual is not None:
        in_specs.append(row_spec)
        args = args + (residual,)
    cs = jnp.stack([jnp.asarray(clip, jnp.float32),
                    jnp.asarray(sigma, jnp.float32)]).reshape(1, 2)
    in_specs.append(pl.BlockSpec((1, 2), lambda i, j: (0, 0),
                                 memory_space=pltpu.SMEM))
    args = args + (cs,)
    return pl.pallas_call(
        functools.partial(_cut_layer_kernel, n_k=n_k,
                          with_residual=residual is not None),
        grid=(M // block_m, n_k),
        in_specs=in_specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, N), jnp.float32)],
        interpret=interpret,
    )(*args)
