"""Public jit'd wrapper for the fused cut-layer op."""
from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.cut_layer.ref import cut_layer_ref
from repro.kernels.cut_layer.kernel import cut_layer_pallas


def cut_layer(x, w, b, *, clip: float, sigma: float, key=None, noise=None,
              use_pallas: bool = False):
    """Fused projection + tanh + L2 clip + Gaussian DP noise.

    Either `noise` (standard normal, shape (M, N)) or a PRNG `key` must be
    given when sigma > 0.
    """
    if noise is None:
        if sigma > 0.0:
            assert key is not None, "need key or noise when sigma > 0"
            noise = jax.random.normal(key, (x.shape[0], w.shape[1]), x.dtype)
        else:
            import jax.numpy as jnp
            noise = jnp.zeros((x.shape[0], w.shape[1]), x.dtype)
    if use_pallas:
        # the kernel clamps block sizes to divisors of (M, K) itself
        return cut_layer_pallas(x, w, b, noise, clip=clip, sigma=sigma,
                                interpret=default_interpret())
    return cut_layer_ref(x, w, b, noise, clip=clip, sigma=sigma)
