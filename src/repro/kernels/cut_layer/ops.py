"""Public wrapper for the fused cut-layer publish op.

The passive party's last bottom layer IS the cut layer, so the whole DP
publish transform — projection, tanh, L2 clip, Gaussian noise — runs as
one fused op and the pre-noise embedding never materializes outside it
(docs/architecture.md §"DP fuses into the cut-layer publish").  Both
replay engines reach this op through `models.tabular.publish_embedding`;
the compiled engine feeds device PRNG noise, the event loop its legacy
host-numpy noise stream.

`use_pallas=True` selects the Pallas TPU kernel (`kernel.py`, exercised
in interpret mode off-TPU); otherwise the jnp reference (`ref.py`) runs
— same math, fused by XLA.
"""
from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.cut_layer.ref import cut_layer_ref
from repro.kernels.cut_layer.kernel import cut_layer_pallas


def cut_layer(x, w, b, *, clip: float, sigma: float, key=None, noise=None,
              residual=None, use_pallas: bool = False):
    """Fused projection + tanh [+ residual] + L2 clip + Gaussian DP noise.

    Either `noise` (standard normal, shape (M, N)) or a PRNG `key` must be
    given when sigma > 0.  `residual` ((M, N), optional) is the skip input
    of the residual "large model" bottom variant, added before the clip.
    """
    if noise is None:
        if sigma > 0.0:
            assert key is not None, "need key or noise when sigma > 0"
            noise = jax.random.normal(key, (x.shape[0], w.shape[1]), x.dtype)
        else:
            import jax.numpy as jnp
            noise = jnp.zeros((x.shape[0], w.shape[1]), x.dtype)
    if use_pallas:
        # the kernel clamps block sizes to divisors of (M, K) itself
        return cut_layer_pallas(x, w, b, noise, residual, clip=clip,
                                sigma=sigma, interpret=default_interpret())
    return cut_layer_ref(x, w, b, noise, clip=clip, sigma=sigma,
                         residual=residual)
