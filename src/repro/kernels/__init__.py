"""Pallas TPU kernels for the compute hot spots.

Each kernel lives in its own subpackage with:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (kernel vs. pure-jnp path selection)
  ref.py    — pure-jnp oracle used for allclose validation

On this CPU-only container kernels run in ``interpret=True`` mode; the
XLA-lowered dry-run uses the jnp path (Mosaic does not lower on host
platform), which is numerically identical per the kernel tests.
"""
import os


def default_interpret() -> bool:
    """interpret=True on CPU; off automatically when a TPU is present."""
    import jax
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] == "1"
    return jax.default_backend() != "tpu"
