"""Pure-jnp oracle for the RG-LRU diagonal gated linear recurrence:

    h_t = a_t * h_{t-1} + u_t

where `a_t` is the data-dependent per-channel decay and `u_t` the gated
input (sqrt(1-a_t^2) * i_t * x_t, computed by the caller).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, u, h0):
    """a, u: (B,S,W); h0: (B,W) fp32 -> (h: (B,S,W), h_last: (B,W))."""
    dtype = u.dtype
    af, uf = a.astype(jnp.float32), u.astype(jnp.float32)

    def step(h, inp):
        a_t, u_t = inp
        h = a_t * h + u_t
        return h, h

    xs = (jnp.moveaxis(af, 1, 0), jnp.moveaxis(uf, 1, 0))
    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(dtype), h_last


def rglru_scan_assoc_ref(a, u, h0):
    """Associative-scan formulation (identical math, O(log S) depth)."""
    dtype = u.dtype
    af, uf = a.astype(jnp.float32), u.astype(jnp.float32)
    uf = uf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    aa, hh = jax.lax.associative_scan(combine, (af, uf), axis=1)
    return hh.astype(dtype), hh[:, -1].astype(jnp.float32)
