"""Public jit'd wrapper for the RG-LRU scan."""
from __future__ import annotations

from repro.kernels import default_interpret
from repro.kernels.rglru_scan.ref import rglru_scan_ref, rglru_scan_assoc_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas


def rglru_scan(a, u, h0, *, use_pallas: bool = False, assoc: bool = False,
               block_t: int = 128):
    if use_pallas:
        B, S, W = a.shape
        bt = block_t
        while S % bt:
            bt //= 2
        bw = 128
        while W % bw:
            bw //= 2
        return rglru_scan_pallas(a, u, h0, block_t=max(bt, 1),
                                 block_w=max(bw, 1),
                                 interpret=default_interpret())
    if assoc:
        return rglru_scan_assoc_ref(a, u, h0)
    return rglru_scan_ref(a, u, h0)
