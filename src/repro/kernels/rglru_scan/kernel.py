"""Pallas TPU kernel for the RG-LRU diagonal gated linear recurrence.

TPU adaptation: channels are embarrassingly parallel (diagonal recurrence),
so the grid tiles (batch, channel_blocks) on the major axes and streams time
chunks on the minor sequential axis; the per-channel fp32 state vector stays
in VMEM scratch across chunks.  Channel blocks are lane-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, u_ref, h0_ref, h_ref, hlast_ref, state,
                  *, block_t: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a_ref[0, t].astype(jnp.float32) * h + \
            u_ref[0, t].astype(jnp.float32)
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, state[...])
    state[...] = h

    @pl.when(c == n_chunks - 1)
    def _finish():
        hlast_ref[0] = h


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan_pallas(a, u, h0, *, block_t: int = 128, block_w: int = 128,
                      interpret: bool = True):
    """a,u: (B,S,W); h0: (B,W) -> (h: (B,S,W), h_last: (B,W) fp32)."""
    B, S, W = a.shape
    block_t = min(block_t, S)
    block_w = min(block_w, W)
    assert S % block_t == 0 and W % block_w == 0, (S, W, block_t, block_w)
    n_chunks = S // block_t

    t_spec = pl.BlockSpec((1, block_t, block_w), lambda b, wi, c: (b, c, wi))
    h_spec = pl.BlockSpec((1, block_w), lambda b, wi, c: (b, wi))
    h, h_last = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t, n_chunks=n_chunks),
        grid=(B, W // block_w, n_chunks),
        in_specs=[t_spec, t_spec, h_spec],
        out_specs=[t_spec, h_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), u.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, u, h0.astype(jnp.float32))
    return h, h_last
