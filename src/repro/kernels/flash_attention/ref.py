"""Pure-jnp oracle for tiled attention: causal / sliding-window / GQA."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, q_offset: int = 0):
    """q: (B,S,Hq,D); k,v: (B,T,Hk,D) with Hq % Hk == 0."""
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qf = (q.astype(jnp.float32) * D ** -0.5).reshape(B, S, Hk, G, D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
