"""Public jit'd wrapper for tiled attention."""
from __future__ import annotations

from typing import Optional

from repro.kernels import default_interpret
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    use_pallas: bool = False):
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            interpret=default_interpret())
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
