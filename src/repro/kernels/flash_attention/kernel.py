"""Pallas TPU flash attention (online softmax), causal / sliding-window / GQA.

TPU adaptation: grid = (batch, q_heads, q_blocks, kv_blocks); the kv axis is
the minor sequential axis so the fp32 (block_q, D) accumulator plus the
running max/denominator stay in VMEM scratch across kv blocks.  Blocks are
MXU-aligned (128 x 128 by default).  GQA is handled in the k/v index_map
(kv head = q head // group), so no repeated-KV materialization in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
                  *, block_q: int, block_k: int, n_kv: int,
                  causal: bool, window: Optional[int], q_offset: int,
                  scale: float):
    qi, kj = pl.program_id(2), pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    s = jnp.dot(q, k.T)                                  # (bq, bk)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_s[...], l_s[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep everything at zero
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc[...] = acc[...] * alpha[:, None] + jnp.dot(
        p, v_ref[0, 0].astype(jnp.float32))
    m_s[...], l_s[...] = m_new, l_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_s[...], 1e-30)
        o_ref[0, 0] = (acc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                              "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B,S,Hq,D); k,v: (B,T,Hk,D)."""
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    block_q, block_k = min(block_q, S), min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0
    n_q, n_kv = S // block_q, T // block_k
    qh = q.transpose(0, 2, 1, 3)                         # (B,Hq,S,D)
    kh = k.transpose(0, 2, 1, 3)                         # (B,Hk,T,D)
    vh = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, n_kv=n_kv,
            causal=causal, window=window, q_offset=q_offset,
            scale=D ** -0.5),
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)
