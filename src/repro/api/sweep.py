"""`run_sweep`: the compile-once/run-many driver over a list of configs.

Two execution modes over the same structural-reuse cache:

* **sequential** (default) — points run in order, each through its own
  `Session` with ``reuse="structural"``, so every point whose structural
  key matches an earlier one reuses that point's compiled program
  (schedule + jitted engine + pinned DES timetable) and only pays model
  init + the actual training scans.
* **point-stacked** (``stacked=True``) — points are first grouped by
  structural key; each multi-point group of compiled-engine points then
  executes point-stacked: per-point model/opt/DP-PRNG state is stacked
  along a new leading point axis, lr/clip/sigma become per-point
  vectors, the pinned tick schedule is broadcast, and the cached epoch
  runners execute vmapped over the point axis
  (`CompiledReplayEngine.run_epoch_stacked`).  A group runs as chunks
  of `stack_chunk` points — one vmapped device program each — with
  chunks on a core-bounded pool of executor threads; the default is
  the whole group in one program on accelerators and per-point chunks
  on CPU (`_default_chunk`), where concurrency recovers the cores
  XLA-CPU's intra-op parallelism leaves idle.  The stacked state is unstacked
  back into ordinary per-point `RunResult`s, so callers see exactly
  the sequential surface.  Per-point results match sequential
  execution bit-for-bit (each point's params, data, hyper scalars and
  noise key are its own; only the *batching* differs) while the
  per-tick dispatch and fixed costs are paid once per chunk instead of
  once per point.  Device memory scales with chunk × (state + data).

`SweepResult.stats` exposes the compile-cache counters, per-point wall
clock, and the structural-group composition (``points_per_group``,
``stacked_groups``), which is how the amortization win is asserted in
CI and tracked in `BENCH_replay.json` (``sweep_reuse`` /
``sweep_stacked`` records).
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax

from repro.api.session import (ExperimentConfig, RunResult, Session,
                               compile_stats)


@dataclass
class SweepResult:
    results: List[RunResult]
    stats: Dict

    def __iter__(self):
        return iter(self.results)

    def __len__(self):
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]


def _group_by_key(cfgs, sessions) -> "Dict[tuple, List[tuple]]":
    """Structural groups in first-seen order: key -> [(index, cfg,
    session), ...].  Calling `structural_key()` prepares/plans a point,
    so the sequential driver only calls this after the runs (when the
    stages are memoized) while the stacked driver calls it up front."""
    groups: "Dict[tuple, List[tuple]]" = {}
    for i, (cfg, sess) in enumerate(zip(cfgs, sessions)):
        groups.setdefault(sess.structural_key(), []).append(
            (i, cfg, sess))
    return groups


def _default_chunk(n_points: int) -> int:
    """Points per stacked device program.  On accelerators the whole
    group is one program — batched gemms are what the hardware wants,
    and the vmapped runner pays the per-tick fixed cost once for every
    point.  On CPU the replay is dot-bound and XLA-CPU gemms scale
    ~linearly under the point axis, so the single big program wins only
    ~1.0-1.1x; the driver instead runs per-point chunks on a
    core-bounded executor pool, recovering the cores a single replay
    leaves idle (~1.4 of 2 utilized).  Recorded total-sweep win on the
    2-core box: ~1.26x (BENCH_replay.json `sweep_stacked` tracks both
    strategies; engine-only, the concurrent chunks reach ~1.5-2x —
    per-point host costs dilute the total).  `stack_chunk=` overrides
    either default."""
    return n_points if jax.default_backend() != "cpu" else 1


def _run_group_stacked(group: List[tuple], *, eval_every_epoch: bool,
                       stack_chunk: Optional[int] = None) -> List[tuple]:
    """Execute one structural group — [(index, cfg, session), ...] —
    point-stacked and unstack to per-point results.

    The group is split into chunks of `stack_chunk` points (default:
    `_default_chunk`); each chunk runs as ONE vmapped device program
    through the group's single compiled engine, and chunks execute on
    concurrent executor threads (independent states; jit calls are
    thread-safe).  Per-point `wall_s` is the group wall clock split
    evenly (the points of a chunk are inseparable on the device)."""
    t0 = time.perf_counter()
    sessions = [sess for _, _, sess in group]
    prog = sessions[0].compile()
    for sess in sessions[1:]:
        sess.compile()                 # cache hits; keeps counters honest
    engine = prog.engine
    engine._ensure_stacked_runners()   # build once, before the threads
    n_epochs = group[0][1].n_epochs

    points = [sess._resolve_point(None, None, None) for sess in sessions]
    n_dev = getattr(engine, "n_devices", 1)
    if stack_chunk is not None:
        chunk = max(1, stack_chunk)
    elif n_dev > 1:
        # mesh engine: the whole group runs as ONE device-sharded
        # program — the point axis lays over the mesh, replacing the
        # core-bounded thread pool (api.session `n_devices=` knob)
        chunk = len(group)
    else:
        chunk = _default_chunk(len(group))
    spans = [range(lo, min(lo + chunk, len(group)))
             for lo in range(0, len(group), chunk)]

    trainers: List = [None] * len(group)
    histories: List[List[float]] = [[] for _ in group]
    results: List = [None] * len(group)

    def final_eval(i, t, state) -> None:
        # the metric `_finish_replay` would otherwise compute serially
        # on the main thread (`trainer.evaluate()` after finish); the
        # replica mean of the final state is the same quantity, so
        # evaluating here keeps the value bit-identical and concurrent
        if not histories[i]:
            histories[i].append(t._metric(*engine.params_mean(state)))

    def run_chunk(span) -> None:
        # per-point model init runs on the chunk's thread too
        for i in span:
            trainers[i] = sessions[i]._make_trainer(*points[i])
        ts = [trainers[i] for i in span]
        if len(span) == 1:
            # singleton chunk: an ordinary single run through the shared
            # driver (the plain runners are already compiled — no P=1
            # vmap trace needed; `finish` syncs on this thread)
            i = span[0]
            results[i] = ts[0].replay_with(
                engine, eval_every_epoch=eval_every_epoch,
                seed=points[i][0])
            return
        # mesh-stacked groups must hold a device multiple of points:
        # pad by repeating the last point — its lanes are redundant
        # compute, never read back (unstacking below walks `span` only)
        pad = (-len(span)) % max(n_dev, 1)
        ts_run = ts + [ts[-1]] * pad
        seeds = [points[i][0] for i in span] + \
            [points[span[-1]][0]] * pad
        data = engine.stage_data_stacked([(t.Xa, t.Xp, t.y)
                                          for t in ts_run])
        state = engine.init_state_stacked(
            [(t.theta_a, t.opt_a, t.theta_p, t.opt_p) for t in ts_run],
            ts[0].d_emb, seeds=seeds)
        hyper = {k: [t.hyper()[k] for t in ts_run]
                 for k in ("lr", "clip", "sigma")}
        for e in range(n_epochs):
            state = engine.run_epoch_stacked(state, e, data, hyper)
            if eval_every_epoch:
                for j, i in enumerate(span):
                    ta, tp = engine.params_mean(
                        engine.point_state(state, j))
                    histories[i].append(ts[j]._metric(ta, tp))
        # drive this chunk's chain to completion on THIS thread: with
        # async dispatch, deferring the sync to the main thread would
        # serialize the chunks' executions again — and finish (the
        # device->host unstack) concurrently per chunk for the same
        # reason
        jax.block_until_ready(state.theta_a)
        for j, i in enumerate(span):
            ps = engine.point_state(state, j)
            final_eval(i, ts[j], ps)
            results[i] = ts[j]._finish_replay(engine, ps, histories[i])

    if len(spans) == 1:
        run_chunk(spans[0])
    else:
        workers = min(len(spans), max(1, os.cpu_count() or 1))
        with ThreadPoolExecutor(workers) as ex:
            list(ex.map(run_chunk, spans))

    wall_each = (time.perf_counter() - t0) / len(group)
    out = []
    for i, (idx, _, sess) in enumerate(group):
        seed, lr, dp_mu = points[i]
        out.append((idx, sess._result(results[i], wall_s=wall_each,
                                      seed=seed, lr=lr, dp_mu=dp_mu)))
    return out


def run_sweep(cfgs: Sequence[ExperimentConfig], *,
              reuse: str = "structural",
              callbacks: Sequence = (),
              eval_every_epoch: bool = True,
              progress: Optional[Callable[[int, RunResult], None]] = None,
              stacked: bool = False,
              stack_chunk: Optional[int] = None
              ) -> SweepResult:
    """Run every config, grouping compiled programs by structural key.

    Sweep points varying only seed / lr / dp_mu / a same-shape dataset
    hit the program cache: the sweep compiles once per distinct shape
    (assert via `stats["compiles"]` / per-point
    `results[i].compile_cache_hit`).  `callbacks` instances are shared
    across points — keep per-run state resettable at epoch 1, as the
    built-ins do, or construct fresh instances per sweep.  Note the
    structural-reuse
    semantics: cache-hit points replay the TIMETABLE (event order, batch
    schedule) of the point that compiled their group, while model init,
    DP noise and hyperparameters are their own — see api.session.
    `reuse="exact"` restores fully per-seed timetables (and compiles
    once per distinct (shape, seed)).

    ``stacked=True`` additionally fuses each multi-point structural
    group of compiled-engine points into vmapped device programs (see
    the module docstring) — per-point results are unchanged, total wall
    clock drops.  `stack_chunk` bounds the points per device program
    (default: the whole group on accelerators; per-point chunks on a
    core-bounded concurrent pool on CPU — see `_default_chunk`).
    Stacking implies structural grouping, so it requires
    ``reuse="structural"``; per-epoch `callbacks` are a per-run surface
    and fall back to sequential execution.  Groups of one point, and
    event-engine points, always run sequentially."""
    if stacked and reuse != "structural":
        raise ValueError("stacked=True fuses structural groups into one "
                         "program and therefore requires "
                         "reuse='structural'")
    t_start = time.perf_counter()
    before = compile_stats()
    sessions = [Session(cfg, reuse=reuse) for cfg in cfgs]
    slots: List[Optional[RunResult]] = [None] * len(cfgs)

    stacked_groups = 0
    group_sizes: List[int] = []
    if stacked and not callbacks:
        # grouping up front prepares/plans each point, which the runs
        # below would do anyway
        for group in _group_by_key(cfgs, sessions).values():
            group_sizes.append(len(group))
            if len(group) > 1 and group[0][1].engine == "compiled" \
                    and not group[0][2]._streaming():
                stacked_groups += 1
                for idx, rr in _run_group_stacked(
                        group, eval_every_epoch=eval_every_epoch,
                        stack_chunk=stack_chunk):
                    slots[idx] = rr
                    # a stacked group's points finish together, so
                    # progress streams per GROUP (point order within it)
                    if progress is not None:
                        progress(idx, rr)
    for i, sess in enumerate(sessions):
        if slots[i] is None:
            slots[i] = sess.run(callbacks=callbacks,
                                eval_every_epoch=eval_every_epoch)
            if progress is not None:
                progress(i, slots[i])
    results: List[RunResult] = slots  # type: ignore[assignment]
    if not group_sizes:
        # sequential path: report composition post-hoc (the sessions are
        # prepared by now, so the keys are memoized lookups)
        group_sizes = [len(g) for g in _group_by_key(cfgs,
                                                     sessions).values()]
    after = compile_stats()
    warm = [r.wall_s for r in results if r.compile_cache_hit]
    cold = [r.wall_s for r in results if not r.compile_cache_hit]
    stats = {
        "n_points": len(results),
        "compiles": after["compiles"] - before["compiles"],
        "cache_hits": after["hits"] - before["hits"],
        "structural_hits": (after["structural_hits"] -
                            before["structural_hits"]),
        "wall_s": time.perf_counter() - t_start,
        "point_wall_s": [r.wall_s for r in results],
        "cold_wall_s_mean": sum(cold) / len(cold) if cold else 0.0,
        "warm_wall_s_mean": sum(warm) / len(warm) if warm else 0.0,
        "points_per_group": group_sizes,
        "stacked_groups": stacked_groups,
    }
    return SweepResult(results=results, stats=stats)
