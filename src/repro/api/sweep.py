"""`run_sweep`: the compile-once/run-many driver over a list of configs.

Points are executed in order, each through its own `Session` with
``reuse="structural"`` by default, so every point whose structural key
matches an earlier one reuses that point's compiled program (schedule +
jitted engine + pinned DES timetable) and only pays model init + the
actual training scans.  `SweepResult.stats` exposes the compile-cache
counters and per-point wall clock, which is how the amortization win is
asserted in CI and tracked in `BENCH_replay.json`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.api.session import (ExperimentConfig, RunResult, Session,
                               compile_stats)


@dataclass
class SweepResult:
    results: List[RunResult]
    stats: Dict

    def __iter__(self):
        return iter(self.results)

    def __len__(self):
        return len(self.results)

    def __getitem__(self, i):
        return self.results[i]


def run_sweep(cfgs: Sequence[ExperimentConfig], *,
              reuse: str = "structural",
              callbacks: Sequence = (),
              eval_every_epoch: bool = True,
              progress: Optional[Callable[[int, RunResult], None]] = None
              ) -> SweepResult:
    """Run every config, grouping compiled programs by structural key.

    Sweep points varying only seed / lr / dp_mu / a same-shape dataset
    hit the program cache: the sweep compiles once per distinct shape
    (assert via `stats["compiles"]` / per-point
    `results[i].compile_cache_hit`).  `callbacks` instances are shared
    across points — keep per-run state resettable at epoch 1, as the
    built-ins do, or construct fresh instances per sweep.  Note the
    structural-reuse
    semantics: cache-hit points replay the TIMETABLE (event order, batch
    schedule) of the point that compiled their group, while model init,
    DP noise and hyperparameters are their own — see api.session.
    `reuse="exact"` restores fully per-seed timetables (and compiles
    once per distinct (shape, seed))."""
    t_start = time.perf_counter()
    before = compile_stats()
    results: List[RunResult] = []
    for i, cfg in enumerate(cfgs):
        sess = Session(cfg, reuse=reuse)
        rr = sess.run(callbacks=callbacks,
                      eval_every_epoch=eval_every_epoch)
        results.append(rr)
        if progress is not None:
            progress(i, rr)
    after = compile_stats()
    warm = [r.wall_s for r in results if r.compile_cache_hit]
    cold = [r.wall_s for r in results if not r.compile_cache_hit]
    stats = {
        "n_points": len(results),
        "compiles": after["compiles"] - before["compiles"],
        "cache_hits": after["hits"] - before["hits"],
        "structural_hits": (after["structural_hits"] -
                            before["structural_hits"]),
        "wall_s": time.perf_counter() - t_start,
        "point_wall_s": [r.wall_s for r in results],
        "cold_wall_s_mean": sum(cold) / len(cold) if cold else 0.0,
        "warm_wall_s_mean": sum(warm) / len(warm) if warm else 0.0,
    }
    return SweepResult(results=results, stats=stats)
