"""Session API: the staged, compile-once/run-many experiment lifecycle.

PubSub-VFL's headline numbers are sweep-shaped — speedups across
datasets, worker grids, batch sizes and DP levels — and a sweep point
shares almost everything with its neighbours.  The Session splits the
old one-shot `run_experiment` monolith into inspectable stages, each
returning an immutable artifact and memoized on the session:

    sess = Session(cfg)
    prep = sess.prepare()     # data load + vertical split + PSI + profile
    plan = sess.plan()        # Algorithm-2 planning (optional) -> RunConfig
    sim  = sess.simulate()    # DES -> event log + system metrics
    prog = sess.compile()     # schedule lowering + replay engine
    out  = sess.run(seed=..., lr=..., dp_mu=..., callbacks=[...])

`compile()` caches the `(CompiledSchedule, engine)` pair process-wide
under a **structural key** — method, engine/pack, shapes (n_samples,
feature dims, batch size, epochs), worker/replica counts, DES timing
knobs, DP on/off — so sweep points that vary only seed, lr, dp_mu, or
swap a same-shape dataset reuse the compiled program instead of paying
data prep + DES + schedule lowering + XLA tracing per point.  The
hyperparameters themselves (`lr`, DP `clip`/`sigma`) are *runtime
scalars* of the jitted runners (see `core.jit_pipeline.EngineSpec`), so
the reuse is a true cache hit, not a retrace.

Two reuse scopes (`Session(cfg, reuse=...)`):

* ``"exact"`` (default) — the cache key includes the config seed, so a
  cached program is only reused for a config that would have produced
  the identical DES timetable.  `run_experiment` uses this: its output
  is bit-equal to the pre-Session monolith.
* ``"structural"`` — the seed is dropped from the lookup, so any
  same-shape program is reused and its **timetable is pinned** to the
  config that first compiled it: a later point varying only the seed
  trains with its own model init / DP noise / lr but replays the cached
  event timetable (batch order included).  This is the `run_sweep`
  default — the DES is a *simulator* of system time, and pinning it
  across seeds is exactly the "same system, different training run"
  comparison the sweeps make.

`run()` executes real training through the engine-agnostic
`ReplayEngine` protocol: a fresh `VFLTrainer` (new param init per seed)
drives the cached engine, per-epoch callbacks replace the hardcoded
eval cadence, and `state=` resumes a `checkpoint.store.save_state`d
mid-training state.
"""
from __future__ import annotations

import dataclasses
import math
import os
import tempfile
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, SimResult, simulate
from repro.core.engines import (CompiledReplayEngine, EventReplayEngine,
                                ReplayEngine, replica_counts)
from repro.core.planner import Plan, plan as run_planner
from repro.core.schedule import compile_schedule
from repro.core.trainer import Callback, TrainResult, VFLTrainer
from repro.data.shards import ArrayFeatures, Permuted
from repro.data.synthetic import load, open_sharded, shape_of, write_sharded
from repro.data.vertical import (VerticalView, psi_align, psi_intersect,
                                 vertical_split)
from repro.core.faults import FaultPlan
from repro.dp.gdp import GDPConfig, noise_sigma


@dataclass
class ExperimentConfig:
    method: str = "pubsub"
    dataset: str = "bank"
    scale: float = 0.05              # dataset size multiplier (CI-friendly)
    n_epochs: int = 5
    batch_size: int = 256
    w_a: int = 8
    w_p: int = 10
    cores_a: int = 32
    cores_p: int = 32
    features_active: Optional[int] = None   # data heterogeneity
    use_planner: bool = False        # let Algo. 2 pick (w_a, w_p, B)
    planner_objective: str = "throughput"  # "paper" = literal Eq. 14
    dp_mu: float = math.inf          # GDP privacy parameter
    seed: int = 0
    resnet: bool = False             # "large model" variant (Table 7)
    depth: int = 10
    # ablations
    disable_deadline: bool = False   # T_ddl = 0-like (w/o T_all)
    disable_semi_async: bool = False # sync every epoch (w/o ΔT)
    disable_planner: bool = False    # fixed equal workers (w/o DP algo)
    engine: str = "compiled"         # replay engine: "compiled" | "event"
    pack: str = "segmented"          # lane layout: "segmented"|"packed"|"dense"
    faults: Optional["FaultPlan"] = None   # deterministic failure
                                     # scenario (core.faults.FaultPlan or
                                     # its to_dict() form) injected into
                                     # the DES — see docs/architecture.md
                                     # §Fault injection & failover
    n_devices: int = 1               # lay the replica/point axes over a
                                     # 1-D ("replica",) device mesh
                                     # (compiled engine, pack != "dense";
                                     # 1 = today's single-device path)
    t_ddl: float = 10.0
    dt0: int = 5
    p: int = 5
    q: int = 5
    jitter: float = 0.10
    lr: float = 1e-3
    # --- streaming data path (docs/architecture.md §Streaming) ---
    # host-RAM budget for staged feature data; when the resident f32
    # feature block would exceed it, prepare() switches to streaming
    # (windowed double-buffered staging) and sizes the window from it
    data_budget_mb: Optional[float] = None
    stream: Optional[bool] = None        # force streaming on/off
                                         # (None = budget-driven auto)
    stream_backing: str = "auto"         # "auto" | "wrap" (in-RAM arrays
                                         # through the windowed path) |
                                         # "shards" (on-disk party shards)
    stream_window_batches: Optional[int] = None  # pin the window size
                                                 # (tests/CI); default:
                                                 # derived from budget
    shard_dir: Optional[str] = None      # shard root (default:
                                         # $REPRO_SHARD_DIR or tmp)
    stream_chunk_rows: int = 131_072     # generator chunk (shards)
    stream_test_cap: int = 65_536        # resident eval rows (shards)


def build_profile(cfg: ExperimentConfig, d_a: int, d_p: int
                  ) -> SystemProfile:
    ref = (d_a + d_p) / 2
    return SystemProfile(
        active=PartyProfile(cores=cfg.cores_a, feature_dim=d_a,
                            ref_feature_dim=ref),
        passive=PartyProfile(cores=cfg.cores_p, feature_dim=d_p,
                             ref_feature_dim=ref),
    )


# ---------------------------------------------------------------------------
# stage artifacts
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Prepared:
    """Stage 1: loaded, vertically split, PSI-aligned data + the system
    profile fitted to its dimensions.  In streaming mode the train
    feature views hold `data.shards` sources (on-disk shard stores or
    wrapped arrays) instead of resident ndarrays; test views are always
    resident (capped in shards mode)."""
    task: str
    train_active: object
    train_passive: object
    test_active: object
    test_passive: object
    profile: SystemProfile
    n_samples: int
    d_a: int
    d_p: int
    streaming: bool = False
    backing: Optional[str] = None    # "wrap" | "shards" when streaming


@dataclass(frozen=True)
class Planned:
    """Stage 2: the resolved (w_a, w_p, B) — planner output when
    `use_planner`, the config's literals otherwise — as a DES-ready
    `RunConfig`."""
    w_a: int
    w_p: int
    batch_size: int
    n_rep_a: int
    n_rep_p: int
    plan: Optional[Plan]
    run_cfg: RunConfig


@dataclass(frozen=True)
class CompiledProgram:
    """Stage 4: everything reusable across runs of the same shape — the
    DES result (the pinned timetable), the lowered schedule, and the
    replay engine holding the jitted runners and device-staged tick
    program.  Cached process-wide; treat as frozen."""
    structural_key: tuple
    full_key: tuple
    engine_kind: str
    planned: Planned
    sim: SimResult
    schedule: object                 # CompiledSchedule (compiled engine)
    engine: ReplayEngine
    dp_on: bool


@dataclass
class RunResult:
    """One training run.  `metrics` is exactly the legacy
    `run_experiment` dict (same keys/values) — new Session-level info
    lives on the dataclass, not in the dict."""
    metrics: Dict
    train: TrainResult
    compile_cache_hit: bool
    wall_s: float
    seed: int
    lr: float
    dp_mu: float
    data_path: Optional[Dict] = None   # streaming staging stats
                                       # (None = resident data path)

    def __getitem__(self, k):
        return self.metrics[k]

    def get(self, k, default=None):
        return self.metrics.get(k, default)


# ---------------------------------------------------------------------------
# the process-wide compiled-program + prepared-data caches
# ---------------------------------------------------------------------------
_PROGRAMS: "OrderedDict[tuple, CompiledProgram]" = OrderedDict()
_BY_STRUCTURE: Dict[tuple, tuple] = {}     # structural key -> full key
_PROGRAM_CAP = 16
_STATS = {"compiles": 0, "hits": 0, "structural_hits": 0}

# loaded/split/PSI-aligned data, shared across sessions: warm sweep
# points (and repeat sessions) skip data prep entirely.  Keyed on every
# input of the data pipeline; the profile is rebuilt per session (it
# also depends on the core counts).
_DATA_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_DATA_CAP = 8


def compile_stats() -> Dict[str, int]:
    """Counters of the process-wide compile cache: `compiles` (misses
    that built a program), `hits` (exact-key reuse), `structural_hits`
    (same-shape reuse across seeds).  The sweep-reuse acceptance check
    asserts on these."""
    return dict(_STATS)


def reset_compile_cache() -> None:
    _PROGRAMS.clear()
    _BY_STRUCTURE.clear()
    _DATA_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


class Session:
    """One experiment configuration, staged.  Stages memoize on the
    session; `compile()` additionally consults the process-wide program
    cache (see module docstring for the reuse scopes)."""

    def __init__(self, cfg: ExperimentConfig, *, reuse: str = "exact",
                 n_devices: Optional[int] = None, faults=None):
        if reuse not in ("exact", "structural"):
            raise ValueError(f"reuse {reuse!r} not in ('exact', "
                             f"'structural')")
        if n_devices is not None:
            cfg = dataclasses.replace(cfg, n_devices=int(n_devices))
        if faults is not None:
            cfg = dataclasses.replace(cfg, faults=faults)
        if isinstance(cfg.faults, dict):      # JSON form (workers, bench)
            cfg = dataclasses.replace(cfg,
                                      faults=FaultPlan.from_dict(cfg.faults))
        if cfg.faults is not None:
            cfg.faults.validate(cfg.method)
        if cfg.n_devices > 1 and cfg.engine != "compiled":
            raise ValueError("n_devices > 1 requires engine='compiled' "
                             f"(got engine={cfg.engine!r})")
        self.cfg = cfg
        self.reuse = reuse
        self._prepared: Optional[Prepared] = None
        self._planned: Optional[Planned] = None
        self._sim: Optional[SimResult] = None
        self._program: Optional[CompiledProgram] = None
        self.compile_cache_hit = False

    # -- stage 1: data + profile ----------------------------------------
    def _streaming(self) -> bool:
        """Whether this config takes the streaming data path: forced by
        `stream=`, else on when the resident f32 feature block would
        exceed `data_budget_mb`, else off (small configs pay nothing)."""
        cfg = self.cfg
        if cfg.stream is not None:
            return bool(cfg.stream)
        if cfg.data_budget_mb is None:
            return False
        n, d, _ = shape_of(cfg.dataset, cfg.scale)
        return n * d * 4 > cfg.data_budget_mb * 1e6

    def _backing(self) -> str:
        """Streaming backing: "shards" when even *holding* the features
        in host RAM would bust the budget (so they are generated
        chunk-by-chunk straight to per-party shard dirs), "wrap"
        otherwise (resident arrays routed through the windowed staging
        path — bit-identical data to the resident run)."""
        cfg = self.cfg
        if cfg.stream_backing in ("wrap", "shards"):
            return cfg.stream_backing
        if cfg.stream_backing != "auto":
            raise ValueError(f"stream_backing {cfg.stream_backing!r} not "
                             "in ('auto', 'wrap', 'shards')")
        if cfg.data_budget_mb is None:
            return "wrap"
        n, d, _ = shape_of(cfg.dataset, cfg.scale)
        return "shards" if n * d * 4 > cfg.data_budget_mb * 1e6 else "wrap"

    def _prepare_resident(self) -> tuple:
        """(task, a_tr, p_tr, a_te, p_te) via the resident load/split/PSI
        pipeline, shared across sessions through `_DATA_CACHE`."""
        cfg = self.cfg
        dkey = (cfg.dataset, cfg.seed, cfg.scale, cfg.features_active)
        if dkey in _DATA_CACHE:
            _DATA_CACHE.move_to_end(dkey)
            return _DATA_CACHE[dkey]
        ds = load(cfg.dataset, seed=cfg.seed, scale=cfg.scale)
        tr, te = ds.split(seed=cfg.seed)
        a_tr, p_tr = vertical_split(
            tr, seed=cfg.seed, n_features_active=cfg.features_active)
        a_te, p_te = vertical_split(
            te, seed=cfg.seed, n_features_active=cfg.features_active)
        a_tr, p_tr = psi_align(a_tr, p_tr)
        entry = (ds.task, a_tr, p_tr, a_te, p_te)
        _DATA_CACHE[dkey] = entry
        while len(_DATA_CACHE) > _DATA_CAP:
            _DATA_CACHE.popitem(last=False)
        return entry

    def _shard_root(self) -> str:
        cfg = self.cfg
        if cfg.shard_dir:
            return cfg.shard_dir
        base = os.environ.get(
            "REPRO_SHARD_DIR",
            os.path.join(tempfile.gettempdir(), "repro_shards"))
        tag = (f"{cfg.dataset}_s{cfg.seed}_x{cfg.scale:g}"
               f"_f{cfg.features_active}")
        return os.path.join(base, tag)

    def _prepare_shards(self) -> tuple:
        """(task, a_tr, p_tr, a_te, p_te) from on-disk per-party shards:
        features are generated chunk-by-chunk straight into each party's
        shard directory (never materializing the full array), PSI runs
        on the chunked digest intersection, and its alignment is applied
        as a row-permutation *view* over the shard stores.  Test rows
        stay resident, capped at `stream_test_cap` (evaluation gathers
        them once)."""
        cfg = self.cfg
        dkey = ("shards", cfg.dataset, cfg.seed, cfg.scale,
                cfg.features_active, cfg.stream_chunk_rows,
                cfg.stream_test_cap, self._shard_root())
        if dkey in _DATA_CACHE:
            _DATA_CACHE.move_to_end(dkey)
            return _DATA_CACHE[dkey]
        root = self._shard_root()
        write_sharded(cfg.dataset, root, seed=cfg.seed, scale=cfg.scale,
                      chunk_rows=cfg.stream_chunk_rows,
                      n_features_active=cfg.features_active)
        meta, store_a, store_p, y, ids_tr, ids_te = open_sharded(root)
        # PSI over the aligned train-row id space (both parties hold the
        # same ids, as in the resident path); the digest-sorted
        # intersection order becomes a permutation view over the shards
        local = np.arange(len(ids_tr), dtype=np.int64)
        ia, ip = psi_intersect(local, local)
        perm_a = ids_tr[ia]
        perm_p = ids_tr[ip]
        a_tr = VerticalView(perm_a, Permuted(store_a, perm_a), y[perm_a])
        p_tr = VerticalView(perm_p, Permuted(store_p, perm_p), None)
        te = ids_te[:max(int(cfg.stream_test_cap), 1)]
        a_te = VerticalView(te, store_a.gather(te), y[te])
        p_te = VerticalView(te, store_p.gather(te), None)
        entry = (meta["task"], a_tr, p_tr, a_te, p_te)
        _DATA_CACHE[dkey] = entry
        while len(_DATA_CACHE) > _DATA_CAP:
            _DATA_CACHE.popitem(last=False)
        return entry

    def prepare(self) -> Prepared:
        if self._prepared is not None:
            return self._prepared
        cfg = self.cfg
        streaming = self._streaming()
        backing = self._backing() if streaming else None
        if backing == "shards":
            task, a_tr, p_tr, a_te, p_te = self._prepare_shards()
        else:
            task, a_tr, p_tr, a_te, p_te = self._prepare_resident()
            if streaming:
                # same bytes as the resident run, staged windowed: the
                # wrapper is what routes stage_data onto the streaming
                # path (and what the parity tests compare against)
                a_tr = VerticalView(a_tr.ids, ArrayFeatures(a_tr.X),
                                    a_tr.y)
                p_tr = VerticalView(p_tr.ids, ArrayFeatures(p_tr.X),
                                    p_tr.y)
        profile = build_profile(cfg, a_tr.X.shape[1], p_tr.X.shape[1])
        self._prepared = Prepared(
            task=task, train_active=a_tr, train_passive=p_tr,
            test_active=a_te, test_passive=p_te, profile=profile,
            n_samples=a_tr.X.shape[0], d_a=a_tr.X.shape[1],
            d_p=p_tr.X.shape[1], streaming=streaming, backing=backing)
        return self._prepared

    # -- stage 2: planning ----------------------------------------------
    def plan(self) -> Planned:
        if self._planned is not None:
            return self._planned
        cfg = self.cfg
        prep = self.prepare()
        w_a, w_p, B = cfg.w_a, cfg.w_p, cfg.batch_size
        plan_obj: Optional[Plan] = None
        if cfg.use_planner and not cfg.disable_planner:
            plan_obj = run_planner(prep.profile, w_a_range=(2, 16),
                                   w_p_range=(2, 16),
                                   objective=cfg.planner_objective)
            w_a, w_p, B = plan_obj.w_a, plan_obj.w_p, plan_obj.batch_size
            B = max(min(B, prep.n_samples // 2), 1)
        run_cfg = RunConfig(
            method=cfg.method, n_samples=prep.n_samples, batch_size=B,
            n_epochs=cfg.n_epochs, w_a=w_a, w_p=w_p, profile=prep.profile,
            p=cfg.p, q=cfg.q,
            t_ddl=(0.0 if cfg.disable_deadline else cfg.t_ddl),
            dt0=cfg.dt0, jitter=cfg.jitter, seed=cfg.seed,
            faults=cfg.faults)
        n_rep_a, n_rep_p = replica_counts(cfg.method, w_a, w_p)
        self._planned = Planned(w_a=w_a, w_p=w_p, batch_size=B,
                                n_rep_a=n_rep_a, n_rep_p=n_rep_p,
                                plan=plan_obj, run_cfg=run_cfg)
        return self._planned

    # -- stage 3: DES -----------------------------------------------------
    def simulate(self) -> SimResult:
        """The discrete-event simulation for THIS config's seed.  When a
        later `compile()` hits the program cache structurally, the
        cached program's (pinned) sim is adopted instead and this stage
        is skipped — call `simulate()` before `compile()` if you need
        this config's own timetable."""
        if self._sim is None:
            self._sim = simulate(self.plan().run_cfg)
        return self._sim

    # -- compile key ------------------------------------------------------
    def _dp_on(self) -> bool:
        return math.isfinite(self.cfg.dp_mu)

    def structural_key(self) -> tuple:
        """Everything that shapes the compiled program EXCEPT the seed:
        two configs with equal structural keys lower to schedules and
        XLA programs of identical shape (the timetables may differ)."""
        cfg = self.cfg
        prep = self.prepare()
        pl = self.plan()
        return (
            ("method", cfg.method), ("engine", cfg.engine),
            ("pack", cfg.pack),
            ("n", prep.n_samples), ("d_a", prep.d_a), ("d_p", prep.d_p),
            ("task", prep.task), ("B", pl.batch_size),
            ("epochs", cfg.n_epochs),
            ("w_a", pl.w_a), ("w_p", pl.w_p),
            ("rep_a", pl.n_rep_a), ("rep_p", pl.n_rep_p),
            ("cores", (cfg.cores_a, cfg.cores_p)),
            ("des", (cfg.t_ddl, cfg.dt0, cfg.p, cfg.q, cfg.jitter)),
            ("ablate", (cfg.disable_deadline, cfg.disable_semi_async)),
            ("model", (cfg.resnet, cfg.depth)),
            ("dp", self._dp_on()),
            ("devices", cfg.n_devices),
            # a fault plan reshapes the event log (and hence the lowered
            # tick program), so faulty configs never share a compiled
            # program with healthy ones — or with other fault plans
            ("faults", cfg.faults.key() if cfg.faults is not None
             else None),
        )

    def compile_key(self) -> tuple:
        return self.structural_key() + (("seed", self.cfg.seed),)

    # -- stage 4: schedule + engine --------------------------------------
    def compile(self) -> CompiledProgram:
        if self._program is not None:
            return self._program
        cfg = self.cfg
        skey = self.structural_key()
        full = self.compile_key()
        hit = None
        if full in _PROGRAMS:
            hit = full
            _STATS["hits"] += 1
        elif self.reuse == "structural" and skey in _BY_STRUCTURE:
            hit = _BY_STRUCTURE[skey]
            _STATS["hits"] += 1
            _STATS["structural_hits"] += 1
        if hit is not None:
            self._program = _PROGRAMS[hit]
            _PROGRAMS.move_to_end(hit)
            self._sim = self._program.sim
            self.compile_cache_hit = True
            return self._program

        pl = self.plan()
        prep = self.prepare()
        sim = self.simulate()
        # default hyper values for the engine; the true per-run values
        # are runtime scalars passed by run()
        sigma0 = noise_sigma(self._gdp(cfg.dp_mu, pl)) if self._dp_on() \
            else 0.0
        clip0 = 1.0 if self._dp_on() else math.inf
        schedule = None
        if cfg.engine == "compiled":
            schedule = compile_schedule(
                pl.run_cfg, sim.events, n_rep_a=pl.n_rep_a,
                n_rep_p=pl.n_rep_p, n_samples=prep.n_samples,
                disable_semi_async=cfg.disable_semi_async, pack=cfg.pack)
            engine: ReplayEngine = CompiledReplayEngine(
                schedule, task=prep.task, resnet=cfg.resnet, clip=clip0,
                sigma=sigma0, lr=cfg.lr, seed=cfg.seed,
                n_devices=cfg.n_devices)
        else:
            engine = EventReplayEngine(
                pl.run_cfg, sim.events, n_rep_a=pl.n_rep_a,
                n_rep_p=pl.n_rep_p, n_samples=prep.n_samples,
                task=prep.task, resnet=cfg.resnet, clip=clip0,
                sigma=sigma0, lr=cfg.lr, seed=cfg.seed,
                disable_semi_async=cfg.disable_semi_async)
        program = CompiledProgram(
            structural_key=skey, full_key=full, engine_kind=cfg.engine,
            planned=pl, sim=sim, schedule=schedule, engine=engine,
            dp_on=self._dp_on())
        _STATS["compiles"] += 1
        _PROGRAMS[full] = program
        _BY_STRUCTURE.setdefault(skey, full)
        while len(_PROGRAMS) > _PROGRAM_CAP:
            old_key, old = _PROGRAMS.popitem(last=False)
            if _BY_STRUCTURE.get(old.structural_key) == old_key:
                del _BY_STRUCTURE[old.structural_key]
        self._program = program
        self.compile_cache_hit = False
        return program

    # -- stage 5: run -----------------------------------------------------
    def _gdp(self, dp_mu: float, pl: Planned) -> Optional[GDPConfig]:
        if not math.isfinite(dp_mu):
            return None
        return GDPConfig(mu=dp_mu, clip=1.0, minibatch=pl.batch_size,
                         global_batch=pl.batch_size,
                         n_queries=pl.run_cfg.n_batches * self.cfg.n_epochs)

    def _resolve_point(self, seed, lr, dp_mu) -> tuple:
        """Fill run-point defaults from the config and validate that
        `dp_mu` keeps DP on/off as compiled (that is structure)."""
        cfg = self.cfg
        seed = cfg.seed if seed is None else seed
        lr = cfg.lr if lr is None else lr
        dp_mu = cfg.dp_mu if dp_mu is None else dp_mu
        if math.isfinite(dp_mu) != self.compile().dp_on:
            raise ValueError(
                "dp_mu flips DP on/off, which is part of the compiled "
                "structure — use a Session whose config matches "
                f"(compiled dp_on={self.compile().dp_on}, got "
                f"dp_mu={dp_mu})")
        return seed, lr, dp_mu

    def window_batches(self) -> Optional[int]:
        """Streaming window size in batches (None on the resident path):
        the pinned `stream_window_batches` if given, else sized so the
        double buffer (two staged windows) fits `data_budget_mb`, else a
        default of 32."""
        if not self._streaming():
            return None
        cfg = self.cfg
        if cfg.stream_window_batches is not None:
            return max(1, int(cfg.stream_window_batches))
        pl = self.plan()
        prep = self.prepare()
        if cfg.data_budget_mb is not None:
            per_batch = pl.batch_size * (prep.d_a + prep.d_p + 1) * 4
            wb = int(cfg.data_budget_mb * 1e6 / 2 // max(per_batch, 1))
            # a window's staged bid count can exceed its tick span by the
            # batches in flight across its boundary (up to one per
            # replica — see jit_pipeline._fixed_window_len), so leave
            # that many batches of slack under the half-budget
            wb -= pl.n_rep_a + pl.n_rep_p
            return max(1, min(wb, max(pl.run_cfg.n_batches, 1)))
        return 32

    def _make_trainer(self, seed: int, lr: float,
                      dp_mu: float) -> VFLTrainer:
        """A fresh `VFLTrainer` (new model init for `seed`) against this
        session's prepared data and compiled plan — the per-point work a
        cache-hit run still pays.  Used by `run()` and, per point, by
        the stacked sweep driver (`api.sweep`)."""
        cfg = self.cfg
        pl = self.compile().planned
        prep = self.prepare()
        return VFLTrainer(
            pl.run_cfg, prep.train_active, prep.train_passive,
            prep.test_active, prep.test_passive, prep.task, lr=lr,
            seed=seed, resnet=cfg.resnet, gdp=self._gdp(dp_mu, pl),
            depth=cfg.depth, disable_semi_async=cfg.disable_semi_async,
            stream_window_batches=self.window_batches())

    def _result(self, res: TrainResult, *, wall_s: float, seed: int,
                lr: float, dp_mu: float) -> RunResult:
        """Wrap a finished `TrainResult` into the legacy-metrics
        `RunResult` for this session's compiled program."""
        cfg = self.cfg
        prog = self.compile()
        prep = self.prepare()
        pl = prog.planned
        sim = prog.sim
        metrics = {
            "method": cfg.method,
            "dataset": cfg.dataset,
            "task": prep.task,
            "metric": res.metric_name,
            "final": res.final_metric,
            "history": res.history,
            "losses": res.losses,
            "sim_s": sim.total_time,
            "sim_s_per_epoch": sim.total_time / max(cfg.n_epochs, 1),
            "cpu_util": sim.cpu_util,
            "waiting_per_epoch": sim.waiting_per_epoch,
            "comm_mb": sim.comm_mb,
            "staleness": res.staleness_mean,
            "lane_occupancy": res.lane_occupancy,
            "drops": sim.stats["drops"],
            "w_a": sim.stats["w_a"],
            "w_p": sim.stats["w_p"],
            "batch_size": pl.batch_size,
            "plan": (pl.plan.summary() if pl.plan else None),
        }
        data_path = None
        if res.data_path is not None:
            data_path = dict(res.data_path)
            data_path["backing"] = prep.backing
            data_path["budget_mb"] = cfg.data_budget_mb
        return RunResult(metrics=metrics, train=res,
                         compile_cache_hit=self.compile_cache_hit,
                         wall_s=wall_s, seed=seed, lr=lr, dp_mu=dp_mu,
                         data_path=data_path)

    def run(self, *, seed: Optional[int] = None, lr: Optional[float] = None,
            dp_mu: Optional[float] = None,
            callbacks: Sequence[Callback] = (),
            eval_every_epoch: bool = True, state=None) -> RunResult:
        """Train against the compiled program.  `seed` re-keys the model
        init and DP noise; `lr` and `dp_mu` override the runtime
        hyperparameters — none of the three invalidates the compiled
        program (DP must stay on/off as compiled, since that is
        structure).  `state` resumes a checkpointed mid-training state
        (`checkpoint.store.restore_state` + `engine.load_state`)."""
        t0 = time.perf_counter()
        prog = self.compile()
        seed, lr, dp_mu = self._resolve_point(seed, lr, dp_mu)
        trainer = self._make_trainer(seed, lr, dp_mu)
        res = trainer.replay_with(prog.engine, callbacks=callbacks,
                                  eval_every_epoch=eval_every_epoch,
                                  state=state, seed=seed)
        return self._result(res, wall_s=time.perf_counter() - t0,
                            seed=seed, lr=lr, dp_mu=dp_mu)
