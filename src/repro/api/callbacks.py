"""Per-epoch callbacks for the replay loop.

A callback is any callable taking a `core.trainer.EpochContext`; the
trainer invokes every callback after each completed epoch.  These
replace the old hardcoded `eval_every_epoch` flag: evaluation cadence,
early stopping, metric streaming and checkpointing are all user
composition now.  `ctx.evaluate()` is lazy and cached per epoch, so
stacking several metric-reading callbacks costs one evaluation.

Typical use with the Session API::

    sess.run(eval_every_epoch=False, callbacks=[
        EvalEvery(5),
        EarlyStop(target=0.92, higher_better=True),
        CheckpointEvery("ckpt.msgpack", every=10),
    ])
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Set, Tuple

from repro.core.trainer import EpochContext


class DriverCrash(RuntimeError):
    """Injected driver-process failure, raised by `Watchdog` after the
    configured epoch completes.  `run_with_failover` treats it as a
    process death: restore the latest checkpoint and resume."""


@dataclass
class EvalEvery:
    """Evaluate every `every` epochs (and on the final epoch) and append
    to the run's history — the custom-cadence replacement for
    `eval_every_epoch=True` (which is equivalent to `EvalEvery(1)`).
    A no-op on epochs already in the history (`ctx.in_history`), so
    composing with `eval_every_epoch=True` never double-appends."""
    every: int = 1

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.in_history:
            return
        if ctx.epoch % self.every == 0 or ctx.epoch == ctx.n_epochs:
            ctx.history.append(ctx.evaluate())
            ctx.in_history = True


@dataclass
class EarlyStop:
    """Stop the replay once the test metric reaches `target` (with an
    optional `patience` of consecutive non-improving epochs).  The
    stopped state is still finishable and checkpoint-resumable.  The
    patience tracker resets whenever a replay starts from its first
    epoch, so one instance can be reused across sweep points (a resumed
    replay, starting at epoch > 1, keeps accumulated state)."""
    target: Optional[float] = None
    higher_better: bool = True
    patience: Optional[int] = None
    _best: Optional[float] = field(default=None, repr=False)
    _bad: int = field(default=0, repr=False)

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.epoch == 1:
            self._best, self._bad = None, 0
        m = ctx.evaluate()
        if self.target is not None:
            if (m >= self.target) if self.higher_better else \
                    (m <= self.target):
                ctx.stop = True
                return
        if self.patience is not None:
            better = self._best is None or \
                ((m > self._best) if self.higher_better else
                 (m < self._best))
            if better:
                self._best, self._bad = m, 0
            else:
                self._bad += 1
                if self._bad >= self.patience:
                    ctx.stop = True


@dataclass
class MetricStream:
    """Stream {epoch, metric} to a sink callable after every epoch —
    progress bars, experiment trackers, live dashboards."""
    sink: Callable[[dict], None]
    evaluate: bool = True

    def __call__(self, ctx: EpochContext) -> None:
        rec = {"epoch": ctx.epoch, "n_epochs": ctx.n_epochs}
        if self.evaluate:
            rec["metric"] = ctx.evaluate()
        self.sink(rec)


@dataclass
class CheckpointEvery:
    """Save the replay state every `every` epochs via
    `checkpoint.store.save_state`; resume with
    `Session.run(state=engine.load_state(restore_state(path)))`.
    The state is canonicalized through `engine.export_state` first, so a
    checkpoint written by a mesh-sharded run (`n_devices=4`) restores on
    any device count — the on-disk layout is always the unpermuted,
    unpadded replica order."""
    path: str
    every: int = 1

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.epoch % self.every == 0 or ctx.epoch == ctx.n_epochs:
            # deferred so `repro.api` imports without msgpack installed
            from repro.checkpoint.store import save_state
            save_state(self.path, ctx.state, step=ctx.epoch,
                       engine=ctx.engine)


@dataclass
class Watchdog:
    """Checkpoint every `every` epochs AND simulate driver-process death
    at the epochs in `crash_at` (raising `DriverCrash` after that
    epoch's checkpoint lands).  Each configured crash fires exactly once
    per instance, so the retry loop in `run_with_failover` makes
    progress instead of dying at the same epoch forever.

    The checkpoint is written before the crash is raised, and
    `replay_with` appends each epoch to the run history before callbacks
    run — so nothing evaluated is lost and a resumed run is bit-identical
    to an uninterrupted one (see tests/test_failover.py)."""
    path: str
    every: int = 1
    crash_at: Tuple[int, ...] = ()
    _fired: Set[int] = field(default_factory=set, repr=False)

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.epoch % self.every == 0 or ctx.epoch == ctx.n_epochs:
            # deferred so `repro.api` imports without msgpack installed
            from repro.checkpoint.store import save_state
            save_state(self.path, ctx.state, step=ctx.epoch,
                       engine=ctx.engine)
        if ctx.epoch in self.crash_at and ctx.epoch not in self._fired:
            self._fired.add(ctx.epoch)
            raise DriverCrash(f"injected driver crash after epoch "
                              f"{ctx.epoch}")


def run_with_failover(session, watchdog: Watchdog, *, callbacks=(),
                      max_restarts: int = 8, **run_kw):
    """Drive `session.run` under a `Watchdog`, restoring from its latest
    checkpoint whenever the driver "dies" (`DriverCrash`) and resuming
    until the run completes.  Corrupt checkpoints surface as
    `CheckpointCorrupt` rather than resuming from garbage.  Returns the
    final `RunResult`; raises after `max_restarts` recoveries."""
    from repro.checkpoint.store import restore_state
    state = run_kw.pop("state", None)
    restarts = 0
    while True:
        try:
            return session.run(state=state,
                               callbacks=[watchdog, *callbacks], **run_kw)
        except DriverCrash:
            restarts += 1
            if restarts > max_restarts:
                raise
            engine = session.compile().engine
            state = engine.load_state(restore_state(watchdog.path))


@dataclass
class History:
    """Collect per-epoch metrics without touching the run's history —
    e.g. to sample a cadence the result dict should not contain."""
    records: List[dict] = field(default_factory=list)

    def __call__(self, ctx: EpochContext) -> None:
        self.records.append({"epoch": ctx.epoch,
                             "metric": ctx.evaluate()})
