"""Per-epoch callbacks for the replay loop.

A callback is any callable taking a `core.trainer.EpochContext`; the
trainer invokes every callback after each completed epoch.  These
replace the old hardcoded `eval_every_epoch` flag: evaluation cadence,
early stopping, metric streaming and checkpointing are all user
composition now.  `ctx.evaluate()` is lazy and cached per epoch, so
stacking several metric-reading callbacks costs one evaluation.

Typical use with the Session API::

    sess.run(eval_every_epoch=False, callbacks=[
        EvalEvery(5),
        EarlyStop(target=0.92, higher_better=True),
        CheckpointEvery("ckpt.msgpack", every=10),
    ])
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.trainer import EpochContext


@dataclass
class EvalEvery:
    """Evaluate every `every` epochs (and on the final epoch) and append
    to the run's history — the custom-cadence replacement for
    `eval_every_epoch=True` (which is equivalent to `EvalEvery(1)`).
    A no-op on epochs already in the history (`ctx.in_history`), so
    composing with `eval_every_epoch=True` never double-appends."""
    every: int = 1

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.in_history:
            return
        if ctx.epoch % self.every == 0 or ctx.epoch == ctx.n_epochs:
            ctx.history.append(ctx.evaluate())
            ctx.in_history = True


@dataclass
class EarlyStop:
    """Stop the replay once the test metric reaches `target` (with an
    optional `patience` of consecutive non-improving epochs).  The
    stopped state is still finishable and checkpoint-resumable.  The
    patience tracker resets whenever a replay starts from its first
    epoch, so one instance can be reused across sweep points (a resumed
    replay, starting at epoch > 1, keeps accumulated state)."""
    target: Optional[float] = None
    higher_better: bool = True
    patience: Optional[int] = None
    _best: Optional[float] = field(default=None, repr=False)
    _bad: int = field(default=0, repr=False)

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.epoch == 1:
            self._best, self._bad = None, 0
        m = ctx.evaluate()
        if self.target is not None:
            if (m >= self.target) if self.higher_better else \
                    (m <= self.target):
                ctx.stop = True
                return
        if self.patience is not None:
            better = self._best is None or \
                ((m > self._best) if self.higher_better else
                 (m < self._best))
            if better:
                self._best, self._bad = m, 0
            else:
                self._bad += 1
                if self._bad >= self.patience:
                    ctx.stop = True


@dataclass
class MetricStream:
    """Stream {epoch, metric} to a sink callable after every epoch —
    progress bars, experiment trackers, live dashboards."""
    sink: Callable[[dict], None]
    evaluate: bool = True

    def __call__(self, ctx: EpochContext) -> None:
        rec = {"epoch": ctx.epoch, "n_epochs": ctx.n_epochs}
        if self.evaluate:
            rec["metric"] = ctx.evaluate()
        self.sink(rec)


@dataclass
class CheckpointEvery:
    """Save the replay state every `every` epochs via
    `checkpoint.store.save_state`; resume with
    `Session.run(state=engine.load_state(restore_state(path)))`.
    The state is canonicalized through `engine.export_state` first, so a
    checkpoint written by a mesh-sharded run (`n_devices=4`) restores on
    any device count — the on-disk layout is always the unpermuted,
    unpadded replica order."""
    path: str
    every: int = 1

    def __call__(self, ctx: EpochContext) -> None:
        if ctx.epoch % self.every == 0 or ctx.epoch == ctx.n_epochs:
            # deferred so `repro.api` imports without msgpack installed
            from repro.checkpoint.store import save_state
            save_state(self.path, ctx.state, step=ctx.epoch,
                       engine=ctx.engine)


@dataclass
class History:
    """Collect per-epoch metrics without touching the run's history —
    e.g. to sample a cadence the result dict should not contain."""
    records: List[dict] = field(default_factory=list)

    def __call__(self, ctx: EpochContext) -> None:
        self.records.append({"epoch": ctx.epoch,
                             "metric": ctx.evaluate()})
