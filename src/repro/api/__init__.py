"""Public experiment API: the staged Session lifecycle.

    from repro.api import Session, ExperimentConfig, run_sweep

    out = Session(ExperimentConfig(method="pubsub")).run()
    sweep = run_sweep([ExperimentConfig(seed=s) for s in range(4)])

See `docs/architecture.md` §Session lifecycle.  The legacy
`repro.core.runtime.run_experiment` is a thin wrapper over
`Session(cfg).run().metrics`.
"""
from repro.api.callbacks import (CheckpointEvery, DriverCrash, EarlyStop,
                                 EvalEvery, History, MetricStream,
                                 Watchdog, run_with_failover)
from repro.api.session import (CompiledProgram, ExperimentConfig, Planned,
                               Prepared, RunResult, Session, build_profile,
                               compile_stats, reset_compile_cache)
from repro.api.sweep import SweepResult, run_sweep
from repro.core.faults import (ChannelDropFault, CrashFault, FaultPlan,
                               StragglerFault)

__all__ = [
    "ChannelDropFault", "CheckpointEvery", "CompiledProgram", "CrashFault",
    "DriverCrash", "EarlyStop", "EvalEvery", "ExperimentConfig",
    "FaultPlan", "History", "MetricStream", "Planned", "Prepared",
    "RunResult", "Session", "StragglerFault", "SweepResult", "Watchdog",
    "build_profile", "compile_stats", "reset_compile_cache",
    "run_sweep", "run_with_failover",
]
