"""Shared layer primitives: init, norms, dense, activations, RoPE/M-RoPE."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def normal_init(key, shape, dtype, stddev=None):
    if stddev is None:
        fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
        stddev = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def zeros_init(key, shape, dtype, **_):
    del key
    return jnp.zeros(shape, dtype)


def init_stacked(key, repeat: int, init_fn):
    """vmap an init function over `repeat` keys -> stacked param pytree."""
    keys = jax.random.split(key, repeat)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def group_norm(x, scale, bias, n_groups: int, eps: float = 64e-5):
    """GroupNorm over the last dim (used by RWKV6 wkv output)."""
    dtype = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------
def dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "tanh": jnp.tanh}[name]


def glu_mlp(params, x, act: str):
    """SwiGLU / GeGLU: (act(x Wg) * x Wu) Wd."""
    g = act_fn(act)(dense(x, params["wg"]))
    u = dense(x, params["wu"])
    return dense(g * u, params["wd"])


def init_glu_mlp(key, d_model, d_ff, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": normal_init(kg, (d_model, d_ff), dtype),
        "wu": normal_init(ku, (d_model, d_ff), dtype),
        "wd": normal_init(kd, (d_ff, d_model), dtype),
    }


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) int32.

    Interleaved (GPT-J) pairing: rotation pairs are ADJACENT elements, so
    the reshape/slice stays device-local under any even sharding of the
    head_dim — the half-split convention forces cross-device
    collective-permutes when head_dim is model-sharded (§Perf finding)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr = x.astype(jnp.float32).reshape(*x.shape[:-1], half, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Multimodal rotary (Qwen2-VL): positions3 (3, B, S) for (t, h, w);
    the frequency axis is partitioned into `sections` (in half-dim units)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                     # (half,)
    # (3, B, S, half)
    ang_all = positions3.astype(jnp.float32)[..., None] * freqs
    # pick the section owner per freq index
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)              # (half,)
    ang = jnp.take_along_axis(
        ang_all, sec_id[None, None, :].astype(jnp.int32)[None], axis=0)[0]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr = x.astype(jnp.float32).reshape(*x.shape[:-1], half, 2)
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def chunked_cross_entropy(h, w_head, labels, *, chunk: int = 1024,
                          ignore_index: int = -100):
    """Vocab-safe CE: logits are materialized per sequence-chunk inside a
    rematerialized scan body, so the (B,S,V) fp32 logits tensor never
    exists (a §Perf memory-term optimization; numerically identical to
    `cross_entropy(h @ w_head, labels)`)."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.concatenate(
            [h, jnp.zeros((B, pad, d), h.dtype)], axis=1)
        labels = jnp.concatenate(
            [labels, jnp.full((B, pad), ignore_index, labels.dtype)],
            axis=1)
    nc = (S + pad) // chunk
    hc = jnp.moveaxis(h.reshape(B, nc, chunk, d), 1, 0)
    yc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        h_c, y_c = xs
        logits = jnp.einsum("bcd,dv->bcv", h_c.astype(jnp.float32),
                            w_head.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(y_c, 0)[..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        mask = (y_c != ignore_index).astype(jnp.float32)
        nll, cnt = carry
        return (nll + ((logz - ll) * mask).sum(), cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, yc))
    return nll / jnp.maximum(cnt, 1.0)


def cross_entropy(logits, labels, ignore_index: int = -100):
    """Mean next-token CE; logits (B,S,V) fp-any, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    mask = (labels != ignore_index).astype(jnp.float32)
    nll = (logz - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
