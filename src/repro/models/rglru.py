"""RecurrentGemma recurrent block: GeGLU-gated causal conv + RG-LRU.

State (decode cache): {"h": (B,W) fp32, "conv": (B, conv_width-1, W)}.
Gate projections are full linear (the reference model uses block-diagonal;
noted as an approximation in DESIGN.md §9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense, normal_init
from repro.kernels.rglru_scan.ops import rglru_scan

_C = 8.0  # RG-LRU decay sharpness constant (Griffin §2.4)


def init_rglru(key, cfg: ArchConfig):
    d, W = cfg.d_model, cfg.resolved_lru_width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wy": normal_init(ks[0], (d, W), dt),
        "wx": normal_init(ks[1], (d, W), dt),
        "conv_w": normal_init(ks[2], (cfg.conv_width, W), dt, stddev=0.1),
        "conv_b": jnp.zeros((W,), dt),
        "wa": normal_init(ks[3], (W, W), dt, stddev=0.02),
        "wi": normal_init(ks[4], (W, W), dt, stddev=0.02),
        "lam": jnp.full((W,), 2.0, dt),   # softplus(2) ~ 2.13 -> slow decay
        "wo": normal_init(ks[5], (W, d), dt),
    }


def init_rglru_state(cfg: ArchConfig, batch: int):
    W = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W),
                          jnp.dtype(cfg.dtype)),
    }


def _causal_conv(z, w, b, conv_state):
    """Depthwise causal conv, width cw.  z: (B,S,W); w: (cw,W)."""
    B, S, W = z.shape
    cw = w.shape[0]
    prev = (conv_state if conv_state is not None
            else jnp.zeros((B, cw - 1, W), z.dtype))
    zp = jnp.concatenate([prev, z], axis=1)          # (B, S+cw-1, W)
    out = jnp.zeros((B, S, W), jnp.float32)
    for i in range(cw):
        out = out + zp[:, i:i + S].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    new_state = zp[:, S:] if conv_state is not None else None
    return out.astype(z.dtype), new_state


def rglru_block(params, cfg: ArchConfig, x, state):
    """x: (B,S,d) -> (out, new_state)."""
    y = jax.nn.gelu(dense(x, params["wy"]))                  # gate branch
    z = dense(x, params["wx"])
    conv_state = state["conv"] if state is not None else None
    z, new_conv = _causal_conv(z, params["conv_w"], params["conv_b"],
                               conv_state)
    # RG-LRU
    r = jax.nn.sigmoid(dense(z, params["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(z, params["wi"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * \
        z.astype(jnp.float32)
    h0 = (state["h"] if state is not None
          else jnp.zeros((x.shape[0], z.shape[-1]), jnp.float32))
    h, h_last = rglru_scan(a.astype(x.dtype), gated.astype(x.dtype), h0)
    out = dense(h.astype(x.dtype) * y, params["wo"])
    new_state = None
    if state is not None:
        new_state = {"h": h_last, "conv": new_conv}
    return out, new_state
