"""The paper's own bottom/top models (§5.1) + decomposed VFL compute ops.

Bottom models: ten-layer MLP ("small") and a residual MLP ("large",
standing in for their ResNet on tabular features).  Top model: two-layer
MLP at the active party.

The decomposed ops are what the runtimes exchange over channels:
  passive_forward(theta_p, x_p)                  -> z_p  (embedding)
  active_step(theta_a, x_a, z_p, y)              -> loss, grads_a, g_zp
  passive_backward(theta_p, x_p, g_zp)           -> grads_p
These mirror Algorithm 1 lines 7-10 / 15-18 / 25-26.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import normal_init


EMB_DIM = 128


def init_bottom(key, d_in: int, *, depth: int = 10, width: int = 128,
                emb_dim: int = EMB_DIM) -> Dict:
    ks = jax.random.split(key, depth + 1)
    dims = [d_in] + [width] * (depth - 1) + [emb_dim]
    layers = []
    for i in range(depth):
        layers.append({
            "w": normal_init(ks[i], (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        })
    return {"layers": layers}


def bottom_forward(params: Dict, x, resnet: bool = False) -> jnp.ndarray:
    h = x
    for lyr in params["layers"]:
        z = jnp.tanh(h @ lyr["w"] + lyr["b"])
        if resnet and z.shape == h.shape:
            z = z + h
        h = z
    return h


def hidden_forward(params: Dict, x, resnet: bool = False) -> jnp.ndarray:
    """Bottom forward through all layers but the last (the cut layer)."""
    h = x
    for lyr in params["layers"][:-1]:
        z = jnp.tanh(h @ lyr["w"] + lyr["b"])
        if resnet and z.shape == h.shape:
            z = z + h
        h = z
    return h


def publish_embedding(theta_p, x_p, noise: Optional[jnp.ndarray] = None, *,
                      clip: float = math.inf, sigma: float = 0.0,
                      resnet: bool = False, use_pallas: bool = False,
                      dynamic: bool = False) -> jnp.ndarray:
    """Passive forward fused with the DP publish transform (device-resident).

    The last bottom layer IS the cut layer, so both bottom variants route
    projection+tanh+L2-clip+noise through the fused `cut_layer` op (Pallas
    kernel on TPU, jnp reference elsewhere) and the pre-noise embedding
    never leaves the kernel.  The residual ("large model") variant keeps
    the cut layer's skip connection by feeding the hidden activation to
    the kernel's residual input; only when the cut layer's shapes make the
    skip inapplicable (emb_dim != hidden width — `bottom_forward` skips it
    there too) does it fall back to a plain projection.

    `dynamic=True` declares the DP transform structurally ON while `clip`
    and `sigma` are *runtime* values (possibly traced scalars): the
    Python fast-path/assert gating is skipped so one compiled program
    serves every (clip, sigma) — the compiled replay engine's sweep-reuse
    path (api/session.py)."""
    if not dynamic:
        if not (sigma > 0.0 or math.isfinite(clip)):
            return bottom_forward(theta_p, x_p, resnet)
        if sigma > 0.0:
            assert noise is not None, "need noise (std normal) when sigma > 0"
    from repro.kernels.cut_layer.ops import cut_layer
    h = hidden_forward(theta_p, x_p, resnet)
    last = theta_p["layers"][-1]
    if noise is None:
        noise = jnp.zeros(h.shape[:-1] + (last["w"].shape[1],), h.dtype)
    residual = h if resnet and h.shape[-1] == last["w"].shape[1] else None
    return cut_layer(h, last["w"], last["b"], clip=clip, sigma=sigma,
                     noise=noise, residual=residual,
                     use_pallas=use_pallas)


def init_top(key, *, emb_dim: int = EMB_DIM, hidden: int = 64) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": normal_init(k1, (2 * emb_dim, hidden), jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": normal_init(k2, (hidden, 1), jnp.float32),
        "b2": jnp.zeros((1,), jnp.float32),
    }


def top_forward(params: Dict, z_a, z_p) -> jnp.ndarray:
    h = jnp.concatenate([z_a, z_p], axis=-1)
    h = jnp.tanh(h @ params["w1"] + params["b1"])
    return (h @ params["w2"] + params["b2"])[..., 0]


def loss_fn(logits, y, task: str):
    if task == "classification":
        y = y.astype(jnp.float32)
        # Eq. 1: binary cross-entropy with logits
        return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                        jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return jnp.mean(jnp.square(logits - y))           # MSE (RMSE reported)


# ---------------------------------------------------------------------------
# decomposed VFL ops (jitted once per task type)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("resnet",))
def passive_forward(theta_p, x_p, *, resnet: bool = False):
    return bottom_forward(theta_p, x_p, resnet)


@functools.partial(jax.jit, static_argnames=("task", "resnet"))
def active_step(theta_a, x_a, z_p, y, *, task: str, resnet: bool = False):
    """theta_a = {"bottom": ..., "top": ...}; returns loss, grads, g_zp."""
    def f(theta_a, z_p):
        z_a = bottom_forward(theta_a["bottom"], x_a, resnet)
        logits = top_forward(theta_a["top"], z_a, z_p)
        return loss_fn(logits, y, task)

    loss, grads = jax.value_and_grad(f, argnums=(0, 1))(theta_a, z_p)
    return loss, grads[0], grads[1]


@functools.partial(jax.jit, static_argnames=("resnet",))
def passive_backward(theta_p, x_p, g_zp, *, resnet: bool = False):
    _, vjp = jax.vjp(lambda t: bottom_forward(t, x_p, resnet), theta_p)
    return vjp(g_zp)[0]


@functools.partial(jax.jit, static_argnames=("task", "resnet"))
def predict(theta_a, theta_p, x_a, x_p, *, task: str, resnet: bool = False):
    z_a = bottom_forward(theta_a["bottom"], x_a, resnet)
    z_p = bottom_forward(theta_p, x_p, resnet)
    logits = top_forward(theta_a["top"], z_a, z_p)
    if task == "classification":
        return jax.nn.sigmoid(logits)
    return logits
