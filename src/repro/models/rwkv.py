"""RWKV6 "Finch" blocks: time-mix (data-dependent decay wkv) + channel-mix.

State (the decode cache of the attention-free arch):
  {"wkv": (B,H,D,D) fp32, "x_tm": (B,d), "x_cm": (B,d)}  (token-shift regs)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense, group_norm, layer_norm, normal_init
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def init_rwkv_tm(key, cfg: ArchConfig):
    d, lora = cfg.d_model, cfg.rwkv_lora_dim
    H = d // cfg.rwkv_head_dim
    Dh = cfg.rwkv_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    return {
        "mu_x": jnp.zeros((d,), dt),
        "mu5": jnp.zeros((5, d), dt),               # w,k,v,r,g lerp bases
        "maa_w1": normal_init(ks[0], (d, 5 * lora), dt, stddev=1e-4),
        "maa_w2": normal_init(ks[1], (5, lora, d), dt, stddev=1e-4),
        "decay_base": jnp.full((d,), -6.0, dt),
        "td_w1": normal_init(ks[2], (d, lora), dt, stddev=1e-4),
        "td_w2": normal_init(ks[3], (lora, d), dt, stddev=1e-4),
        "u": normal_init(ks[4], (H, Dh), dt, stddev=0.5),
        "wr": normal_init(ks[5], (d, d), dt),
        "wk": normal_init(ks[6], (d, d), dt),
        "wv": normal_init(ks[7], (d, d), dt),
        "wg": normal_init(ks[8], (d, d), dt),
        "wo": normal_init(ks[9], (d, d), dt),
        "lnx_s": jnp.ones((d,), dt),
        "lnx_b": jnp.zeros((d,), dt),
    }


def init_rwkv_cm(key, cfg: ArchConfig):
    d, ff = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dt),
        "mu_r": jnp.zeros((d,), dt),
        "wk": normal_init(ks[0], (d, ff), dt),
        "wv": normal_init(ks[1], (ff, d), dt),
        "wr": normal_init(ks[2], (d, d), dt),
    }


def init_rwkv_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "wkv": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "x_tm": jnp.zeros((batch, d), dt),
        "x_cm": jnp.zeros((batch, d), dt),
    }


def _token_shift(x, prev):
    """sx[t] = x[t-1] with sx[0] = prev (the last token of the prior chunk)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def rwkv_time_mix(params, cfg: ArchConfig, x, state):
    B, S, d = x.shape
    H, Dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    prev = state["x_tm"] if state is not None else jnp.zeros_like(x[:, 0])
    sx = _token_shift(x, prev)
    xx = sx - x

    # data-dependent lerp (ddlerp) for the five mixes
    xxx = x + xx * params["mu_x"].astype(x.dtype)
    low = jnp.tanh(dense(xxx, params["maa_w1"])).reshape(
        B, S, 5, cfg.rwkv_lora_dim)
    deltas = jnp.einsum("bsfl,fld->bsfd", low,
                        params["maa_w2"].astype(x.dtype))     # (B,S,5,d)
    mixed = x[:, :, None] + xx[:, :, None] * (
        params["mu5"].astype(x.dtype)[None, None] + deltas)
    xw, xk, xv, xr, xg = [mixed[:, :, i] for i in range(5)]

    # data-dependent decay  w = exp(-exp(.))  in (0,1)
    ww = params["decay_base"].astype(jnp.float32) + dense(
        jnp.tanh(dense(xw, params["td_w1"])), params["td_w2"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, S, H, Dh)

    r = dense(xr, params["wr"]).reshape(B, S, H, Dh)
    k = dense(xk, params["wk"]).reshape(B, S, H, Dh)
    v = dense(xv, params["wv"]).reshape(B, S, H, Dh)
    g = jax.nn.silu(dense(xg, params["wg"]))

    wkv0 = (state["wkv"] if state is not None
            else jnp.zeros((B, H, Dh, Dh), jnp.float32))
    y, wkv = rwkv6_scan(r, k, v, w.astype(x.dtype), params["u"], wkv0)
    y = group_norm(y.reshape(B, S, d), params["lnx_s"], params["lnx_b"], H)
    out = dense(y * g, params["wo"])
    new_state = None
    if state is not None:
        new_state = dict(state, wkv=wkv, x_tm=x[:, -1])
    return out, new_state


def rwkv_channel_mix(params, cfg: ArchConfig, x, state):
    prev = state["x_cm"] if state is not None else jnp.zeros_like(x[:, 0])
    sx = _token_shift(x, prev)
    xx = sx - x
    xk = x + xx * params["mu_k"].astype(x.dtype)
    xr = x + xx * params["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(xk, params["wk"])))
    out = jax.nn.sigmoid(dense(xr, params["wr"])) * dense(k, params["wv"])
    new_state = dict(state, x_cm=x[:, -1]) if state is not None else None
    return out, new_state
