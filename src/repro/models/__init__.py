"""Model stack: backbones for all assigned architectures + the paper's
tabular models, wrapped by transformer.SplitModel into the two-party
split (bottom | cut layer | f_a + top + head)."""
