"""SplitModel: any assigned backbone wrapped into the two-party split
(bottom stack @ passive party | cut layer | f_a + top stack + head @ active).

Layers are scanned per stage (stacked params) so the traced HLO stays small
for 48-layer configs.  `cut_layer` is the trust boundary (DESIGN.md §3-4):
projection + tanh + L2-clip + Gaussian-DP noise, fused in the Pallas kernel
on TPU (jnp-identical path inside jit).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Stage
from repro.models import blocks
from repro.models.common import (chunked_cross_entropy, cross_entropy,
                                 dense, init_stacked, normal_init,
                                 rms_norm)
from repro.kernels.cut_layer.ops import cut_layer as cut_layer_op


# ---------------------------------------------------------------------------
# stage splitting at the cut layer
# ---------------------------------------------------------------------------
def split_stages(stages: Tuple[Stage, ...], cut: int
                 ) -> Tuple[Tuple[Stage, ...], Tuple[Stage, ...]]:
    """Split a stage list at layer index `cut` (rounded down to the nearest
    pattern-group boundary of the stage it falls in)."""
    bottom: List[Stage] = []
    top: List[Stage] = []
    start = 0
    for repeat, pattern in stages:
        plen = len(pattern)
        n = repeat * plen
        end = start + n
        if end <= cut:
            bottom.append((repeat, pattern))
        elif start >= cut:
            top.append((repeat, pattern))
        else:
            g = (cut - start) // plen          # groups into bottom
            if g > 0:
                bottom.append((g, pattern))
            if repeat - g > 0:
                top.append((repeat - g, pattern))
        start = end
    return tuple(bottom), tuple(top)


# ---------------------------------------------------------------------------
class SplitModel:
    def __init__(self, cfg: ArchConfig):
        cfg.validate()
        self.cfg = cfg
        self.bottom_stages, self.top_stages = split_stages(
            cfg.resolved_stages, cfg.resolved_cut)

    # -- init ---------------------------------------------------------------
    def _init_stage(self, key, stage: Stage):
        repeat, pattern = stage
        keys = jax.random.split(key, len(pattern))
        return tuple(
            init_stacked(keys[i], repeat,
                         lambda k, spec=spec: blocks.init_layer(
                             k, self.cfg, spec))
            for i, spec in enumerate(pattern))

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        ks = iter(jax.random.split(key, 8 + len(cfg.resolved_stages) * 2))
        params: dict = {}
        if cfg.frontend != "audio_frames":
            params["embed"] = normal_init(next(ks), (cfg.vocab_size,
                                                     cfg.d_model), dt,
                                          stddev=0.02)
        params["bottom"] = [self._init_stage(next(ks), s)
                            for s in self.bottom_stages]
        params["cut"] = {
            "w": normal_init(next(ks), (cfg.d_model, cfg.d_model), dt),
            "b": jnp.zeros((cfg.d_model,), dt),
        }
        params["f_a"] = {
            "w1": normal_init(next(ks), (cfg.d_active, cfg.d_model), dt),
            "b1": jnp.zeros((cfg.d_model,), dt),
            "w2": normal_init(next(ks), (cfg.d_model, cfg.d_model), dt),
            "b2": jnp.zeros((cfg.d_model,), dt),
        }
        params["top"] = [self._init_stage(next(ks), s)
                         for s in self.top_stages]
        params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
        if not cfg.tie_embeddings and cfg.frontend != "audio_frames":
            params["head"] = normal_init(next(ks), (cfg.d_model,
                                                    cfg.vocab_size), dt,
                                         stddev=0.02)
        elif cfg.frontend == "audio_frames":
            params["head"] = normal_init(next(ks), (cfg.d_model,
                                                    cfg.vocab_size), dt,
                                         stddev=0.02)
        return params

    # -- caches ---------------------------------------------------------------
    def _init_stage_cache(self, stage: Stage, batch: int, capacity: int):
        repeat, pattern = stage
        out = []
        for spec in pattern:
            single = blocks.init_layer_cache(self.cfg, spec, batch, capacity)
            out.append(jax.tree.map(
                lambda a: jnp.zeros((repeat,) + a.shape, a.dtype), single))
        return tuple(out)

    def init_cache(self, batch: int, capacity: int) -> dict:
        return {
            "t": jnp.zeros((), jnp.int32),
            "bottom": [self._init_stage_cache(s, batch, capacity)
                       for s in self.bottom_stages],
            "top": [self._init_stage_cache(s, batch, capacity)
                    for s in self.top_stages],
        }

    # -- stage application ----------------------------------------------------
    def _apply_stage(self, stage_params, stage: Stage, x, positions, cache,
                     aux):
        repeat, pattern = stage

        def body(carry, xs):
            x, aux = carry
            layer_params, layer_cache = xs
            new_caches = []
            for i, spec in enumerate(pattern):
                c = None if layer_cache is None else layer_cache[i]
                x, c2, a = blocks.apply_layer(layer_params[i], self.cfg,
                                              spec, x, positions, c)
                aux = aux + a
                new_caches.append(c2)
            ys = None if layer_cache is None else tuple(new_caches)
            return (x, aux), ys

        if self.cfg.remat:
            if self.cfg.remat_policy == "dots":
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            else:
                body = jax.checkpoint(body)
        (x, aux), new_cache = jax.lax.scan(
            body, (x, aux), (stage_params, cache))
        return x, new_cache, aux

    def _run_stack(self, stage_params_list, stages, x, positions, caches,
                   aux):
        new_caches = []
        for i, stage in enumerate(stages):
            c = None if caches is None else caches[i]
            x, c2, aux = self._apply_stage(stage_params_list[i], stage, x,
                                           positions, c, aux)
            new_caches.append(c2)
        return x, (None if caches is None else new_caches), aux

    # -- positions -------------------------------------------------------------
    def _positions(self, batch: int, seq: int, t0):
        cfg = self.cfg
        pos = t0 + jnp.arange(seq)[None, :].astype(jnp.int32)
        pos = jnp.broadcast_to(pos, (batch, seq))
        if cfg.mrope:
            return jnp.stack([pos, pos, pos])        # text-style default
        return pos

    def _vlm_positions(self, batch: int, n_vis: int, n_text: int):
        """M-RoPE stub grid: vision patches at t=0 with (h, w) raster;
        text continues temporally after the grid (Qwen2-VL §3.2)."""
        g = max(1, int(math.ceil(math.sqrt(n_vis))))
        idx = jnp.arange(n_vis, dtype=jnp.int32)
        vt = jnp.zeros((n_vis,), jnp.int32)
        vh, vw = idx // g, idx % g
        t0 = g  # text starts after the max grid extent
        tt = t0 + jnp.arange(n_text, dtype=jnp.int32)
        p_t = jnp.concatenate([vt, tt])
        p_h = jnp.concatenate([vh, tt])
        p_w = jnp.concatenate([vw, tt])
        pos = jnp.stack([p_t, p_h, p_w])[:, None, :]
        return jnp.broadcast_to(pos, (3, batch, n_vis + n_text))

    # -- embedding of the passive party's raw inputs ---------------------------
    def _embed_passive(self, params, batch: dict, t0):
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            x = batch["tokens_p"].astype(jnp.dtype(cfg.dtype))
            B, S = x.shape[:2]
            return x, self._positions(B, S, t0)
        toks = batch["tokens_p"]
        emb = params["embed"]
        x_tok = emb[toks].astype(jnp.dtype(cfg.dtype))
        if cfg.frontend == "vision_patches" and "patches_p" in batch:
            pat = batch["patches_p"].astype(x_tok.dtype)
            x = jnp.concatenate([pat, x_tok], axis=1)
            B = x.shape[0]
            pos = self._vlm_positions(B, pat.shape[1], toks.shape[1])
            return x, pos
        B, S = x_tok.shape[:2]
        return x_tok, self._positions(B, S, t0)

    # -- full forward -----------------------------------------------------------
    def forward(self, params, batch: dict, *, cache=None, dp_sigma: float = 0.0,
                dp_clip: float = 1e9, rng=None, use_pallas_cut: bool = False,
                return_hidden: bool = False):
        """Returns (logits | hidden, new_cache, aux)."""
        cfg = self.cfg
        t0 = cache["t"] if cache is not None else jnp.zeros((), jnp.int32)
        x, positions = self._embed_passive(params, batch, t0)
        B, S, _ = x.shape
        aux = jnp.zeros((), jnp.float32)

        bcache = None if cache is None else cache["bottom"]
        x, bcache, aux = self._run_stack(params["bottom"],
                                         self.bottom_stages, x, positions,
                                         bcache, aux)

        # ---- cut layer: the trust boundary (passive -> active) ----
        z = cut_layer_op(
            x.reshape(B * S, cfg.d_model), params["cut"]["w"],
            params["cut"]["b"], clip=dp_clip, sigma=dp_sigma, key=rng,
            use_pallas=use_pallas_cut).reshape(B, S, cfg.d_model)

        # ---- active party: f_a on its private features + top stack ----
        xa = batch["x_a"].astype(z.dtype)
        fa = jnp.tanh(dense(xa, params["f_a"]["w1"], params["f_a"]["b1"]))
        fa = dense(fa, params["f_a"]["w2"], params["f_a"]["b2"])
        h = z + fa

        tcache = None if cache is None else cache["top"]
        h, tcache, aux = self._run_stack(params["top"], self.top_stages, h,
                                         positions, tcache, aux)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        if return_hidden:
            new_cache = None
            if cache is not None:
                new_cache = {"t": t0 + S, "bottom": bcache, "top": tcache}
            return h, new_cache, aux
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", h,
                                params["embed"].astype(h.dtype))
        else:
            logits = dense(h, params["head"])
        new_cache = None
        if cache is not None:
            new_cache = {"t": t0 + S, "bottom": bcache, "top": tcache}
        return logits, new_cache, aux

    # -- losses -----------------------------------------------------------------
    def loss(self, params, batch: dict, *, dp_sigma: float = 0.0,
             dp_clip: float = 1e9, rng=None):
        cfg = self.cfg
        if cfg.ce_chunk > 0:
            h, _, aux = self.forward(params, batch, dp_sigma=dp_sigma,
                                     dp_clip=dp_clip, rng=rng,
                                     return_hidden=True)
            w_head = (params["embed"].T if cfg.tie_embeddings
                      else params["head"])
            labels = batch["labels"]
            if cfg.causal:
                h, labels = h[:, :-1], labels[:, 1:]
            if cfg.frontend == "vision_patches":
                h = h[:, -labels.shape[1]:] \
                    if h.shape[1] > labels.shape[1] else h
                labels = labels[:, -h.shape[1]:]
            return chunked_cross_entropy(h, w_head, labels,
                                         chunk=cfg.ce_chunk) + aux
        logits, _, aux = self.forward(params, batch, dp_sigma=dp_sigma,
                                      dp_clip=dp_clip, rng=rng)
        labels = batch["labels"]
        if self.cfg.causal:
            # next-token prediction; labels are the same stream
            lo, la = logits[:, :-1], labels[:, 1:]
        else:
            lo, la = logits, labels
        if self.cfg.frontend == "vision_patches":
            # only the text suffix carries labels
            lo = lo[:, -la.shape[1]:] if lo.shape[1] > la.shape[1] else lo
            la = la[:, -lo.shape[1]:]
        return cross_entropy(lo, la) + aux

    def decode_step(self, params, batch: dict, cache):
        """One-token serve step: batch has tokens_p (B,1) [+ x_a (B,1,d_a)]."""
        logits, cache, _ = self.forward(params, batch, cache=cache)
        return logits[:, -1], cache
