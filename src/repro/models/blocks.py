"""Per-layer assembly: (mixer, ffn) dispatch, init + apply + cache init."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import init_glu_mlp, glu_mlp, layer_norm, rms_norm


# ---------------------------------------------------------------------------
def init_layer(key, cfg: ArchConfig, spec: LayerSpec):
    mixer, ffn = spec
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    km, kf = jax.random.split(key)
    p = {"n1": jnp.zeros((d,), dt)}
    if mixer in ("attn", "local_attn"):
        p["mixer"] = attn.init_attn(km, cfg)
    elif mixer == "mla":
        p["mixer"] = attn.init_mla(km, cfg)
    elif mixer == "rwkv":
        p["n1b"] = jnp.zeros((d,), dt)
        p["mixer"] = rwkv_mod.init_rwkv_tm(km, cfg)
    elif mixer == "rglru":
        p["mixer"] = rglru_mod.init_rglru(km, cfg)
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["n2"] = jnp.zeros((d,), dt)
        if ffn == "dense":
            p["ffn"] = init_glu_mlp(kf, d, cfg.d_ff, dt)
        elif ffn == "moe":
            p["ffn"] = moe_mod.init_moe(kf, cfg)
        elif ffn == "rwkv_cm":
            p["n2b"] = jnp.zeros((d,), dt)
            p["ffn"] = rwkv_mod.init_rwkv_cm(kf, cfg)
        else:
            raise ValueError(ffn)
    return p


def init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     capacity: int):
    mixer, _ = spec
    if mixer in ("attn", "local_attn"):
        cap = capacity
        if cfg.sliding_window is not None:
            cap = min(cap, cfg.sliding_window)
        return attn.init_attn_cache(cfg, batch, cap)
    if mixer == "mla":
        cap = capacity
        if cfg.sliding_window is not None:
            cap = min(cap, cfg.sliding_window)
        return attn.init_mla_cache(cfg, batch, cap)
    if mixer == "rwkv":
        return rwkv_mod.init_rwkv_state(cfg, batch)
    if mixer == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
def _norm1(p, cfg, x):
    if "n1b" in p:
        return layer_norm(x, 1.0 + p["n1"], p["n1b"], cfg.norm_eps)
    return rms_norm(x, p["n1"], cfg.norm_eps)


def _norm2(p, cfg, x):
    if "n2b" in p:
        return layer_norm(x, 1.0 + p["n2"], p["n2b"], cfg.norm_eps)
    return rms_norm(x, p["n2"], cfg.norm_eps)


def _pin(cfg, x):
    """Pin the residual stream to (batch-sharded, replicated) — stops SPMD
    resharding churn between mixer/FFN sub-blocks (§Perf lever)."""
    if cfg.act_spec:
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(
            x, P(tuple(cfg.act_spec), None, None))
    return x


def apply_layer(p, cfg: ArchConfig, spec: LayerSpec, x, positions, cache):
    """Returns (x, new_cache, aux_loss)."""
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    h = _norm1(p, cfg, x)
    if mixer == "attn":
        y, cache = attn.attn_forward(p["mixer"], cfg, h, positions, cache)
    elif mixer == "local_attn":
        y, cache = attn.attn_forward(p["mixer"], cfg, h, positions, cache,
                                     local=True)
    elif mixer == "mla":
        y, cache = attn.mla_forward(p["mixer"], cfg, h, positions, cache)
    elif mixer == "rwkv":
        y, cache = rwkv_mod.rwkv_time_mix(p["mixer"], cfg, h, cache)
    elif mixer == "rglru":
        y, cache = rglru_mod.rglru_block(p["mixer"], cfg, h, cache)
    else:
        raise ValueError(mixer)
    x = _pin(cfg, x + y)
    if ffn != "none":
        h = _norm2(p, cfg, x)
        if ffn == "dense":
            y = glu_mlp(p["ffn"], h, cfg.act)
        elif ffn == "moe":
            y, aux = moe_mod.moe_forward(p["ffn"], cfg, h)
        elif ffn == "rwkv_cm":
            y, cache = rwkv_mod.rwkv_channel_mix(p["ffn"], cfg, h, cache)
        x = _pin(cfg, x + y)
    return x, cache, aux
