"""Mixture-of-Experts FFN with top-k routing, shared experts, capacity-based
scatter/gather dispatch (TPU-friendly: no (T,E,cap) one-hot; FLOPs scale with
*active* experts so MoE rooflines are honest), and a load-balance aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import init_glu_mlp, normal_init, act_fn


def init_moe(key, cfg: ArchConfig):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    kr, ke, ks = jax.random.split(key, 3)
    keys = jax.random.split(ke, 3)
    p = {
        "router": normal_init(kr, (d, E), dt, stddev=0.02),
        "wg": normal_init(keys[0], (E, d, ff), dt),
        "wu": normal_init(keys[1], (E, d, ff), dt),
        "wd": normal_init(keys[2], (E, ff, d), dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_glu_mlp(ks, d, ff * cfg.n_shared_experts, dt)
    return p


def _positions_in_expert(flat_e, E: int, cfg: ArchConfig):
    """Rank of each (token, choice) within its expert's arrival order.

    §Perf iteration log (EXPERIMENTS.md):
    v1  flat cumsum over the (T*k, E) one-hot — lowers to a QUADRATIC
        reduce-window in XLA (O((Tk)^2): 55 PFLOP/device at 1M tokens).
    v2  hierarchical block cumsum — O(Tk*E) work (44x flops reduction) but
        still materializes O(Tk*E) position tensors (memory-dominant).
    v3  (current) sort-based ranking — O(Tk log Tk), NO E-wide tensor:
        stable-sort tokens by expert; rank within the sorted segment is
        arrival order; scatter ranks back."""
    n = flat_e.shape[0]
    fe = flat_e.astype(jnp.int32)
    s = jnp.argsort(fe, stable=True)                      # group by expert
    sorted_e = fe[s]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=jnp.int32))
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - seg_start[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[s].set(pos_sorted)


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(((cap + 7) // 8) * 8, 8)  # round up to a multiple of 8


def moe_forward(params, cfg: ArchConfig, x):
    """x: (B,S,d) -> (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T,E)
    gate, expert_idx = jax.lax.top_k(probs, k)                 # (T,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                    # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = one_hot_top1.mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    cap = _capacity(T, cfg)
    # position of each (token, choice) within its expert's capacity buffer
    flat_e = expert_idx.reshape(-1)                            # (T*k,)
    pos_in_e = _positions_in_expert(flat_e, E, cfg)
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_e * cap + pos_in_e, E * cap)   # overflow slot

    # scatter tokens into (E*cap+1, d)
    src = jnp.repeat(xt, k, axis=0)                            # (T*k,d)
    buf = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].add(
        jnp.where(keep[:, None], src, 0))
    buf = buf[:-1].reshape(E, cap, d)
    if cfg.act_spec:
        # §Perf v5: pin the dispatch buffer to BOTH mesh axes — experts
        # over "model" AND capacity slots over the data axes.  Without
        # this XLA shards the expert einsum over tokens only (the model
        # axis idles: 16x more compute per device than the mesh affords).
        from jax.sharding import PartitionSpec as P
        dp = tuple(cfg.act_spec)
        buf = jax.lax.with_sharding_constraint(buf, P("model", dp, None))

    # expert computation (active FLOPs only: E * cap ≈ T*k*capacity_factor)
    act = act_fn(cfg.act)
    def _pin_e(t):
        if cfg.act_spec:
            from jax.sharding import PartitionSpec as P
            dp = tuple(cfg.act_spec)
            return jax.lax.with_sharding_constraint(
                t, P("model", dp, *([None] * (t.ndim - 2))))
        return t
    g = act(_pin_e(jnp.einsum("ecd,edf->ecf", buf,
                              params["wg"].astype(x.dtype))))
    u = _pin_e(jnp.einsum("ecd,edf->ecf", buf,
                          params["wu"].astype(x.dtype)))
    yb = _pin_e(jnp.einsum("ecf,efd->ecd", g * u,
                           params["wd"].astype(x.dtype)))
    yb = jnp.concatenate(
        [yb.reshape(E * cap, d), jnp.zeros((1, d), x.dtype)], axis=0)

    # gather back and combine with gates
    gathered = yb[slot].reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", gathered,
                   gate.astype(jnp.float32).astype(x.dtype))
    if "shared" in params:
        from repro.models.common import glu_mlp
        y = y + glu_mlp(params["shared"], xt, cfg.act)
    return y.reshape(B, S, d), aux
