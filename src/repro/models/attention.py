"""Attention mixers: GQA (RoPE/M-RoPE, QKV-bias, sliding window) and
DeepSeek-V2 MLA (latent-compressed KV with absorbed decode path).

All functions are cache-carrying:
  forward(params, cfg, x, positions, cache) -> (y, new_cache)
`cache=None` means train/prefill without cache emission; a cache dict means
either prefill-fill (S>1) or single-token decode (S==1).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (apply_mrope, apply_rope, dense, normal_init,
                                 rms_norm)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core scaled-dot-product with GQA groups + causal / sliding-window masks
# ---------------------------------------------------------------------------
def sdpa(q, k, v, *, causal: bool, window: Optional[int],
         q_offset, kv_len=None, scale=None):
    """q: (B,S,Hq,Dh), k/v: (B,T,Hk,Dh).  q_offset is the absolute position
    of q[:,0]; kv_len (scalar) masks unfilled cache slots."""
    B, S, Hq, Dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    scale = scale if scale is not None else Dh ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(B, S, Hk, G, Dh)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bshgd,bthd->bhgst", qf, kf)          # (B,Hk,G,S,T)

    q_pos = q_offset + jnp.arange(S)[:, None]                  # (S,1)
    k_pos = jnp.arange(T)[None, :]                             # (1,T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, v.shape[-1]).astype(q.dtype)


# threshold above which the XLA path switches to blockwise (flash-style)
# attention.  §Perf finding: at S=T=4096 the dense path materializes the
# (B,Hk,G,S,T) fp32 score tensor and its backward all-reduces it (7.5 GB
# per layer on qwen2-0.5b train_4k) — so anything >= 2k x 2k goes blockwise.
_BLOCKWISE_AREA = 2048 * 2048


def blockwise_sdpa(q, k, v, *, causal: bool, window: Optional[int],
                   q_offset, kv_len=None, scale=None,
                   q_chunk: int = 1024, kv_chunk: int = 1024):
    """Online-softmax attention with lax.scan over q and kv chunks.

    Pure-jnp twin of kernels/flash_attention for the compiled dry-run
    (Mosaic does not lower on the host platform).  Same math, O(chunk^2)
    transient memory."""
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    Dv = v.shape[-1]
    scale = scale if scale is not None else D ** -0.5
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0
    nq, nk = S // q_chunk, T // kv_chunk

    # bf16 dot inputs + fp32 accumulation (MXU-style); halves chunk traffic
    cdt = jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32
    qf = (q.astype(jnp.float32) * scale).astype(cdt).reshape(
        B, nq, q_chunk, Hk, G, D)
    qf = jnp.moveaxis(qf, 1, 0)                        # (nq,B,qc,Hk,G,D)
    kf = jnp.moveaxis(k.astype(cdt).reshape(B, nk, kv_chunk, Hk, D), 1, 0)
    vf = jnp.moveaxis(v.astype(cdt).reshape(B, nk, kv_chunk, Hk, Dv), 1, 0)

    def q_block(carry, inp):
        qi, qb = inp                                   # qb: (B,qc,Hk,G,D)

        def kv_block(st, kinp):
            kj, kb, vb = kinp
            acc, m, l = st
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32)
            q_pos = q_offset + qi * q_chunk + \
                jnp.arange(q_chunk)[:, None]
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            if kv_len is not None:
                mask &= k_pos < kv_len
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + \
                jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(cdt), vb,
                           preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        init = (
            jnp.zeros((B, Hk, G, q_chunk, Dv), jnp.float32),
            jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((B, Hk, G, q_chunk), jnp.float32),
        )
        (acc, m, l), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), kf, vf))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hk,G,qc,Dv)
        return carry, jnp.moveaxis(out, 3, 1)          # (B,qc,Hk,G,Dv)

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qf))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, Dv)
    return out.astype(q.dtype)


def dispatch_sdpa(q, k, v, **kw):
    """Dense for small problems, blockwise beyond the area threshold."""
    S, T = q.shape[1], k.shape[1]
    if S * T >= _BLOCKWISE_AREA and S > 1 and \
            S % 1024 == 0 and T % 1024 == 0:
        return blockwise_sdpa(q, k, v, **kw)
    return sdpa(q, k, v, **kw)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hk = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d, hq * hd), dt),
        "wk": normal_init(ks[1], (d, hk * hd), dt),
        "wv": normal_init(ks[2], (d, hk * hd), dt),
        "wo": normal_init(ks[3], (hq * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hk * hd,), dt)
        p["bv"] = jnp.zeros((hk * hd,), dt)
    return p


def init_attn_cache(cfg: ArchConfig, batch: int, capacity: int):
    hd, hk = cfg.resolved_head_dim, cfg.n_kv_heads
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, capacity, hk, hd), dt),
        "v": jnp.zeros((batch, capacity, hk, hd), dt),
        "idx": jnp.zeros((), jnp.int32),
    }


def attn_forward(params, cfg: ArchConfig, x, positions, cache,
                 *, local: bool = False):
    B, S, _ = x.shape
    hd, hq, hk = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = dense(x, params["wq"], params.get("bq")).reshape(B, S, hq, hd)
    k = dense(x, params["wk"], params.get("bk")).reshape(B, S, hk, hd)
    v = dense(x, params["wv"], params.get("bv")).reshape(B, S, hk, hd)

    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        pos1d = positions[0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos1d = positions

    window = cfg.sliding_window if (local or cfg.sliding_window) else None
    if cache is None:
        q_off = pos1d[0, 0]
        out = dispatch_sdpa(q, k, v, causal=cfg.causal, window=window,
                            q_offset=q_off)
    else:
        idx = cache["idx"]
        cap = cache["k"].shape[1]
        if S == 1:
            # decode: ring-buffer write at idx % cap (rope pre-applied)
            slot = idx % cap
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
            kv_len = jnp.minimum(idx + 1, cap)
            # with rope pre-applied all filled slots are attendable; the
            # window is enforced by the ring capacity itself.
            out = sdpa(q, ck, cv, causal=False, window=None,
                       q_offset=idx, kv_len=kv_len)
        else:
            # prefill-fill: write the (last `cap`) keys into the cache
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k[:, -cap:], (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v[:, -cap:], (0, 0, 0, 0))
            out = dispatch_sdpa(q, k, v, causal=cfg.causal, window=window,
                                q_offset=pos1d[0, 0])
        cache = {"k": ck, "v": cv, "idx": idx + S}
    y = dense(out.reshape(B, S, hq * hd), params["wo"])
    return y, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): KV compressed to kv_lora_rank + shared RoPE key
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ArchConfig):
    d, r = cfg.d_model, cfg.kv_lora_rank
    h = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "wq": normal_init(ks[0], (d, h * (nope + rope)), dt),
        "w_dkv": normal_init(ks[1], (d, r + rope), dt),       # down + k_pe
        "kv_norm": jnp.zeros((r,), dt),
        "w_uk": normal_init(ks[2], (r, h * nope), dt),        # up: k_nope
        "w_uv": normal_init(ks[3], (r, h * vd), dt),          # up: v
        "wo": normal_init(ks[4], (h * vd, d), dt),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, capacity: int):
    dt = jnp.dtype(cfg.dtype)
    return {
        "ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dt),
        "kpe": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dt),
        "idx": jnp.zeros((), jnp.int32),
    }


def _mla_project(params, cfg, x, positions):
    B, S, _ = x.shape
    h = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = dense(x, params["wq"]).reshape(B, S, h, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    dkv = dense(x, params["w_dkv"])
    ckv = rms_norm(dkv[..., :cfg.kv_lora_rank], params["kv_norm"],
                   cfg.norm_eps)
    k_pe = apply_rope(dkv[..., None, cfg.kv_lora_rank:], positions,
                      cfg.rope_theta)[:, :, 0]                 # (B,S,rope)
    return q_nope, q_pe, ckv, k_pe


def mla_forward(params, cfg: ArchConfig, x, positions, cache):
    B, S, _ = x.shape
    h, nope, rope, vd = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                         cfg.v_head_dim)
    scale = (nope + rope) ** -0.5
    q_nope, q_pe, ckv, k_pe = _mla_project(params, cfg, x, positions)

    if S > 1:
        # naive (non-absorbed) path for train/prefill
        T = S
        k_nope = dense(ckv, params["w_uk"]).reshape(B, T, h, nope)
        v = dense(ckv, params["w_uv"]).reshape(B, T, h, vd)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, T, h, rope))],
            axis=-1)
        window = cfg.sliding_window
        out = dispatch_sdpa(q, k, v, causal=cfg.causal, window=window,
                            q_offset=positions[0, 0], scale=scale)
        new_cache = None
        if cache is not None:
            cap = cache["ckv"].shape[1]
            cc = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv[:, -cap:], (0, 0, 0))
            cp = jax.lax.dynamic_update_slice(
                cache["kpe"], k_pe[:, -cap:], (0, 0, 0))
            new_cache = {"ckv": cc, "kpe": cp, "idx": cache["idx"] + S}
    else:
        # absorbed decode: attend in the latent space
        idx = cache["idx"]
        cap = cache["ckv"].shape[1]
        slot = idx % cap
        cc = jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, slot, 0))
        cp = jax.lax.dynamic_update_slice(cache["kpe"], k_pe, (0, slot, 0))
        kv_len = jnp.minimum(idx + 1, cap)
        w_uk = params["w_uk"].reshape(cfg.kv_lora_rank, h, nope)
        # q̃ = q_nope absorbed through W_uk:   (B,S,h,r)
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        scores = (jnp.einsum("bshr,btr->bhst", q_lat,
                             cc.astype(jnp.float32))
                  + jnp.einsum("bshp,btp->bhst", q_pe.astype(jnp.float32),
                               cp.astype(jnp.float32))) * scale
        t_pos = jnp.arange(cap)[None, :]
        mask = t_pos < kv_len
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out_lat = jnp.einsum("bhst,btr->bshr", probs,
                             cc.astype(jnp.float32))           # (B,S,h,r)
        w_uv = params["w_uv"].reshape(cfg.kv_lora_rank, h, vd)
        out = jnp.einsum("bshr,rhv->bshv", out_lat,
                         w_uv.astype(jnp.float32)).astype(x.dtype)
        new_cache = {"ckv": cc, "kpe": cp, "idx": idx + S}
    y = dense(out.reshape(B, S, h * vd), params["wo"])
    return y, new_cache
