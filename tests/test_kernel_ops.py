"""ops.py wrappers: the public kernel entry points work under jit with
both the Pallas (interpret) and jnp paths, and agree."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cut_layer.ops import cut_layer
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan


def test_flash_ops_paths_agree():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    a = flash_attention(q, k, v, causal=True, use_pallas=False)
    b = flash_attention(q, k, v, causal=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_rwkv_ops_paths_agree():
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    B, S, H, D = 1, 24, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    y1, f1 = rwkv6_scan(r, k, v, w, u, s0, use_pallas=False)
    y2, f2 = rwkv6_scan(r, k, v, w, u, s0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)


def test_rglru_ops_all_paths():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 32, 16)))
    u = jax.random.normal(ks[1], (2, 32, 16))
    h0 = jax.random.normal(ks[2], (2, 16))
    h1, _ = rglru_scan(a, u, h0, use_pallas=False)
    h2, _ = rglru_scan(a, u, h0, use_pallas=False, assoc=True)
    h3, _ = rglru_scan(a, u, h0, use_pallas=True)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h3), atol=1e-4)


def test_cut_layer_ops_key_path():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (32, 16))
    w = jax.random.normal(ks[1], (16, 8)) * 0.1
    b = jnp.zeros((8,))
    out = cut_layer(x, w, b, clip=1.0, sigma=0.2, key=ks[2])
    out2 = cut_layer(x, w, b, clip=1.0, sigma=0.2, key=ks[2],
                     use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-5)
    # deterministic given the same key
    out3 = cut_layer(x, w, b, clip=1.0, sigma=0.2, key=ks[2])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out3))
