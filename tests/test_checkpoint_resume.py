"""Checkpoint-resume: `TrainerState`/`EventState` round-trip through
`checkpoint.store.save_state`/`restore_state` and resumed replays match
uninterrupted runs bit-for-bit on BOTH engines, DP included — each
engine's DP noise comes from a counter-based `jax.random` stream whose
key lives in the saved state (`TrainerState.key` / `EventState.key`),
so a restored checkpoint continues the exact noise sequence."""
import math

import numpy as np
import pytest

from repro.api import CheckpointEvery, ExperimentConfig, Session
from repro.checkpoint.store import restore_state, save_state

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=4,
            batch_size=64, w_a=4, w_p=4)


def _cfg(**kw):
    d = dict(BASE)
    d.update(kw)
    return ExperimentConfig(**d)


class _StopAfter:
    def __init__(self, k):
        self.k = k

    def __call__(self, ctx):
        if ctx.epoch == self.k:
            ctx.stop = True


def _interrupt_and_resume(cfg, tmp_path, k=2, **run_kw):
    """Run to epoch k with a checkpoint, then resume from disk."""
    path = str(tmp_path / "state.msgpack")
    sess = Session(cfg)
    sess.run(callbacks=[CheckpointEvery(path, every=k), _StopAfter(k)],
             **run_kw)
    engine = sess.compile().engine
    state = engine.load_state(restore_state(path))
    assert int(state.epoch) == k
    resumed = sess.run(state=state, **run_kw)
    return resumed


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_resume_matches_uninterrupted_bitwise(engine, tmp_path):
    cfg = _cfg(engine=engine)
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    # losses cover ALL epochs (buckets 0..k-1 ride in the saved state)
    assert resumed.train.losses == full.train.losses
    # resumed history covers epochs k+1..n and must match exactly
    assert resumed.train.history == full.train.history[2:]
    assert resumed["final"] == full["final"]


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_resume_across_methods(engine, tmp_path):
    cfg = _cfg(engine=engine, method="vfl_ps")
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    assert resumed.train.losses == full.train.losses
    assert resumed["final"] == full["final"]


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_resume_dp_is_bitwise(engine, tmp_path):
    """Each engine's DP noise key is part of the state (compiled: the
    scan-carry key; event: `EventState.key`, a counter-based jax.random
    stream split once per publish), so even DP runs resume
    bit-for-bit."""
    cfg = _cfg(engine=engine, dp_mu=0.5)
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    assert resumed.train.losses == full.train.losses
    assert resumed["final"] == full["final"]


def test_event_dp_noise_stream_sanity(tmp_path):
    """DP semantics on the event engine: runs are deterministic per
    seed, losses stay finite, and heavy noise does not beat the clean
    run."""
    cfg = _cfg(engine="event", dp_mu=0.5)
    r1 = Session(cfg).run()
    r2 = Session(cfg).run()
    assert r1.train.losses == r2.train.losses
    assert all(math.isfinite(l) for l in r1.train.losses)
    clean = Session(_cfg(engine="event")).run()
    assert r1["final"] <= clean["final"] + 0.02


def test_event_load_state_migrates_pre_key_layout():
    """An 11-field EventState payload (pre-PR5: no PRNG key, epoch at
    index 10) still loads: the key is reseeded from (seed, epoch) —
    the old clip/sigma-semantic resume — instead of crashing."""
    cfg = _cfg(engine="event", dp_mu=0.5)
    sess = Session(cfg)
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    state = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                           t.d_emb, seed=0)
    legacy = list(state)[:10] + [2]          # drop key, epoch=2 at f[10]
    got = eng.load_state(tuple(legacy))
    assert int(got.epoch) == 2
    assert got.key is not None
    # deterministic migration: same payload -> same key
    again = eng.load_state(tuple(legacy))
    np.testing.assert_array_equal(np.asarray(got.key),
                                  np.asarray(again.key))


def test_save_state_roundtrip_nested_structures(tmp_path):
    """`save_state`/`restore_state` reproduce dicts (str and int keys),
    lists, tuples and array leaves without a `like` template."""
    import jax.numpy as jnp
    state = (
        [{"w": np.arange(6, dtype=np.float32).reshape(2, 3),
          "b": jnp.ones((3,), jnp.float32)}],
        {3: (np.float32(1.5), 7), "k": [True, None]},
        4,
    )
    path = str(tmp_path / "nested.msgpack")
    save_state(path, state, step=4)
    got = restore_state(path)
    assert isinstance(got, tuple) and len(got) == 3
    np.testing.assert_array_equal(got[0][0]["w"], state[0][0]["w"])
    np.testing.assert_array_equal(got[0][0]["b"], np.ones((3,)))
    assert got[1][3][1] == 7 and int(got[2]) == 4
    assert got[1]["k"][0] in (True, 1) and got[1]["k"][1] is None


# ---------------------------------------------------------------------------
# satellite: checkpoint integrity (state-v2 crc)
# ---------------------------------------------------------------------------
def _save_sample(tmp_path):
    from repro.checkpoint.store import load_step
    path = str(tmp_path / "ck.msgpack")
    state = {"w": np.arange(32, dtype=np.float32), "epoch": 3}
    save_state(path, state, step=3)
    assert load_step(path) == 3
    got = restore_state(path)
    np.testing.assert_array_equal(got["w"], state["w"])
    return path, open(path, "rb").read()


def test_restore_state_rejects_truncated_file(tmp_path):
    from repro.checkpoint.store import CheckpointCorrupt
    path, raw = _save_sample(tmp_path)
    for cut in (0, 1, len(raw) // 2, len(raw) - 1):
        with open(path, "wb") as f:
            f.write(raw[:cut])
        with pytest.raises(CheckpointCorrupt):
            restore_state(path)


def test_restore_state_rejects_bit_flips(tmp_path):
    from repro.checkpoint.store import CheckpointCorrupt
    path, raw = _save_sample(tmp_path)
    # flip a bit in several spots, including deep inside the array
    # payload where pre-crc decoding would have silently succeeded
    for pos in (len(raw) // 3, len(raw) // 2, len(raw) - 8):
        bad = bytearray(raw)
        bad[pos] ^= 0x10
        with open(path, "wb") as f:
            f.write(bytes(bad))
        with pytest.raises(CheckpointCorrupt):
            restore_state(path)
    # pristine bytes still restore (the writer wasn't just failing)
    with open(path, "wb") as f:
        f.write(raw)
    np.testing.assert_array_equal(restore_state(path)["w"],
                                  np.arange(32, dtype=np.float32))


def test_restore_state_reads_legacy_v1(tmp_path):
    """Pre-checksum checkpoints (fmt=state-v1) stay restorable."""
    import msgpack

    from repro.checkpoint.store import _encode, load_step
    path = str(tmp_path / "v1.msgpack")
    state = {"w": np.ones((4,), np.float32)}
    payload = {"state": _encode(state), "step": 2, "fmt": "state-v1"}
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload))
    np.testing.assert_array_equal(restore_state(path)["w"], state["w"])
    assert load_step(path) == 2


def test_restore_state_rejects_foreign_files(tmp_path):
    import msgpack

    from repro.checkpoint.store import CheckpointCorrupt
    path = str(tmp_path / "foreign.msgpack")
    with open(path, "wb") as f:
        f.write(msgpack.packb({"fmt": "who-knows", "x": 1}))
    with pytest.raises(CheckpointCorrupt):
        restore_state(path)
    with open(path, "wb") as f:
        f.write(b"not msgpack at all \x00\xff")
    with pytest.raises(CheckpointCorrupt):
        restore_state(path)
