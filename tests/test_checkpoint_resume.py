"""Checkpoint-resume: `TrainerState`/`EventState` round-trip through
`checkpoint.store.save_state`/`restore_state` and resumed replays match
uninterrupted runs bit-for-bit (non-DP, both engines; DP is also
bitwise on the compiled engine — its PRNG key lives in the state —
while the event engine's host-numpy noise stream keeps clip/sigma
semantics only)."""
import math

import numpy as np
import pytest

from repro.api import CheckpointEvery, ExperimentConfig, Session
from repro.checkpoint.store import restore_state, save_state

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=4,
            batch_size=64, w_a=4, w_p=4)


def _cfg(**kw):
    d = dict(BASE)
    d.update(kw)
    return ExperimentConfig(**d)


class _StopAfter:
    def __init__(self, k):
        self.k = k

    def __call__(self, ctx):
        if ctx.epoch == self.k:
            ctx.stop = True


def _interrupt_and_resume(cfg, tmp_path, k=2, **run_kw):
    """Run to epoch k with a checkpoint, then resume from disk."""
    path = str(tmp_path / "state.msgpack")
    sess = Session(cfg)
    sess.run(callbacks=[CheckpointEvery(path, every=k), _StopAfter(k)],
             **run_kw)
    engine = sess.compile().engine
    state = engine.load_state(restore_state(path))
    assert int(state.epoch) == k
    resumed = sess.run(state=state, **run_kw)
    return resumed


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_resume_matches_uninterrupted_bitwise(engine, tmp_path):
    cfg = _cfg(engine=engine)
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    # losses cover ALL epochs (buckets 0..k-1 ride in the saved state)
    assert resumed.train.losses == full.train.losses
    # resumed history covers epochs k+1..n and must match exactly
    assert resumed.train.history == full.train.history[2:]
    assert resumed["final"] == full["final"]


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_resume_across_methods(engine, tmp_path):
    cfg = _cfg(engine=engine, method="vfl_ps")
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    assert resumed.train.losses == full.train.losses
    assert resumed["final"] == full["final"]


def test_resume_dp_compiled_is_bitwise(tmp_path):
    """The compiled engine's DP noise key is part of the state, so even
    DP runs resume bit-for-bit."""
    cfg = _cfg(dp_mu=0.5)
    full = Session(cfg).run()
    resumed = _interrupt_and_resume(cfg, tmp_path)
    assert resumed.train.losses == full.train.losses
    assert resumed["final"] == full["final"]


def test_resume_dp_event_keeps_clip_sigma_semantics(tmp_path):
    """The event engine's host-numpy noise stream is reseeded on resume,
    so bitwise equality is NOT promised — but the clip/sigma semantics
    hold: the resumed run completes, its DP losses stay finite and
    in range, and resuming twice from the same checkpoint is
    deterministic."""
    cfg = _cfg(engine="event", dp_mu=0.5)
    full = Session(cfg).run()
    r1 = _interrupt_and_resume(cfg, tmp_path, k=2)
    r2 = _interrupt_and_resume(cfg, tmp_path, k=2)
    assert r1.train.losses == r2.train.losses       # deterministic resume
    assert all(math.isfinite(l) for l in r1.train.losses)
    assert len(r1.train.losses) == len(full.train.losses)
    # epochs before the interrupt were saved in-state: identical
    assert r1.train.losses[:2] == full.train.losses[:2]
    # heavy noise should not beat the clean run
    clean = Session(_cfg(engine="event")).run()
    assert r1["final"] <= clean["final"] + 0.02


def test_save_state_roundtrip_nested_structures(tmp_path):
    """`save_state`/`restore_state` reproduce dicts (str and int keys),
    lists, tuples and array leaves without a `like` template."""
    import jax.numpy as jnp
    state = (
        [{"w": np.arange(6, dtype=np.float32).reshape(2, 3),
          "b": jnp.ones((3,), jnp.float32)}],
        {3: (np.float32(1.5), 7), "k": [True, None]},
        4,
    )
    path = str(tmp_path / "nested.msgpack")
    save_state(path, state, step=4)
    got = restore_state(path)
    assert isinstance(got, tuple) and len(got) == 3
    np.testing.assert_array_equal(got[0][0]["w"], state[0][0]["w"])
    np.testing.assert_array_equal(got[0][0]["b"], np.ones((3,)))
    assert got[1][3][1] == 7 and int(got[2]) == 4
    assert got[1]["k"][0] in (True, 1) and got[1]["k"][1] is None
