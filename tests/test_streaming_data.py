"""Streaming data path: chunked PSI parity, the shard store, and
bit-for-bit streaming-vs-resident training equality (ISSUE 6).

The contract under test is exactness, not approximation: the windowed
double-buffered path must replay the SAME batches in the SAME order with
the SAME DP noise as the all-at-once resident path, so every comparison
here is `==` / `assert_array_equal`, never allclose.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np
import pytest

from repro.api import ExperimentConfig, Session
from repro.checkpoint.store import restore_state, save_state
from repro.data.shards import (ArrayFeatures, Permuted, ShardStore,
                               is_feature_source, write_array_shards)
from repro.data.vertical import _hash_ids, psi_intersect
from repro.data.synthetic import (iter_classification_chunks, open_sharded,
                                  shape_of, write_sharded)

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=2,
            batch_size=64, w_a=4, w_p=4, dp_mu=0.5)

STREAM = dict(stream=True, stream_backing="wrap")


# ---------------------------------------------------------------------------
# satellite: vectorized `_hash_ids` pins the legacy per-row digests
# ---------------------------------------------------------------------------
# sha256(b"psi-session" + id.to_bytes(8, "little"))[:32], computed by the
# pre-vectorization per-row `hashlib` loop — frozen so any change to the
# chunked path that would re-align PSI differently fails loudly
PINNED = {
    0: "923439833606276c110ad2dbc0a041bf",
    1: "5a8c6a1f229745c3a4384427f37be851",
    2: "e3779b3c65a4d82f154917873f3ca9e7",
    5: "e162a597103bb1218d35bfdc976acadf",
    1099511627776: "c63b1b02c004806c9e844a09410f4f2a",
    123456789: "ecb32a25a2c43004b124421800eea966",
}


def _legacy_digests(ids, salt=b"psi-session"):
    return np.array([hashlib.sha256(
        salt + int(i).to_bytes(8, "little", signed=True)
    ).hexdigest()[:32] for i in np.asarray(ids)])


def test_hash_ids_pins_legacy_digests():
    ids = np.array(sorted(PINNED), dtype=np.int64)
    got = _hash_ids(ids, b"psi-session")
    assert list(got) == [PINNED[int(i)] for i in ids]
    np.testing.assert_array_equal(got, _legacy_digests(ids))


def test_hash_ids_chunking_invariant():
    ids = np.arange(1000, dtype=np.int64) * 7 + 3
    ref = _hash_ids(ids, b"psi-session")
    for chunk in (1, 3, 257, 4096):
        np.testing.assert_array_equal(
            _hash_ids(ids, b"psi-session", chunk=chunk), ref)
    np.testing.assert_array_equal(ref, _legacy_digests(ids))


def test_psi_intersect_matches_legacy_intersect1d():
    rng = np.random.default_rng(7)
    ids_a = rng.choice(5000, size=900, replace=False).astype(np.int64)
    ids_p = rng.choice(5000, size=1100, replace=False).astype(np.int64)
    ia, ip = psi_intersect(ids_a, ids_p, chunk=257)
    # the pre-streaming implementation: per-row digests + np.intersect1d
    da, dp_ = _legacy_digests(ids_a), _legacy_digests(ids_p)
    _, ref_a, ref_p = np.intersect1d(da, dp_, return_indices=True,
                                     assume_unique=True)
    np.testing.assert_array_equal(ia, ref_a)
    np.testing.assert_array_equal(ip, ref_p)
    np.testing.assert_array_equal(ids_a[ia], ids_p[ip])


# ---------------------------------------------------------------------------
# shard store
# ---------------------------------------------------------------------------
def test_shard_store_roundtrip_and_views(tmp_path):
    X = np.random.default_rng(0).normal(size=(1000, 7)).astype(np.float32)
    write_array_shards(str(tmp_path / "party"), X, rows_per_shard=128)
    store = ShardStore.open(str(tmp_path / "party"))
    assert store.shape == X.shape and is_feature_source(store)
    rows = np.array([0, 999, 128, 127, 5, 5, 640])
    np.testing.assert_array_equal(store.gather(rows), X[rows])
    np.testing.assert_array_equal(store[rows], X[rows])
    perm = np.random.default_rng(1).permutation(1000)
    view = Permuted(store, perm)
    assert is_feature_source(view) and view.shape == X.shape
    np.testing.assert_array_equal(view[rows], X[perm[rows]])
    wrapped = ArrayFeatures(X)
    assert is_feature_source(wrapped)
    np.testing.assert_array_equal(wrapped[rows], X[rows])
    assert not is_feature_source(X)


def test_threaded_gather_bytes_identical_to_sequential(tmp_path):
    """The per-shard gather thread pool must be a pure latency
    optimization: same bytes as the sequential path, in any regime
    (auto below/above the engage threshold, forced pool, forced
    sequential, duplicate + reversed + single-shard row patterns)."""
    rng = np.random.default_rng(7)
    X = rng.normal(size=(5000, 6)).astype(np.float32)
    d = str(tmp_path / "party")
    write_array_shards(d, X, rows_per_shard=256)
    seq = ShardStore.open(d, gather_workers=1)
    auto = ShardStore.open(d)
    forced = ShardStore.open(d, gather_workers=3)
    patterns = [
        rng.integers(0, 5000, size=8192),          # above auto threshold
        rng.integers(0, 5000, size=64),            # below it
        np.arange(5000)[::-1],                     # reversed full scan
        np.repeat(np.array([0, 4999, 256, 255]), 5),   # dupes, edges
        np.arange(100, 200),                       # single shard
        np.array([], np.int64),                    # empty
    ]
    for rows in patterns:
        want = X[rows]
        for store in (seq, auto, forced):
            got = store.gather(rows)
            assert got.tobytes() == want.tobytes()
    assert forced._pool is not None      # forced pool actually engaged
    assert seq._pool is None
    forced.close()
    auto.close()
    assert forced._pool is None


def test_sharded_generator_deterministic_and_idempotent(tmp_path):
    root = str(tmp_path / "credit")
    write_sharded("credit", root, seed=3, scale=0.01, chunk_rows=100,
                  rows_per_shard=64)
    meta, sa, sp, y, ids_tr, ids_te = open_sharded(root)
    n, d, task = shape_of("credit", 0.01)
    assert (meta["n"], meta["d"], meta["task"]) == (n, d, task)
    assert sa.shape[0] == sp.shape[0] == n == len(y)
    assert sa.shape[1] + sp.shape[1] == d
    assert sorted(np.concatenate([ids_tr, ids_te])) == list(range(n))
    # chunk stream is deterministic and matches what landed on disk
    Xs, ys = [], []
    for _, Xc, yc in iter_classification_chunks("credit", n, seed=3,
                                                chunk_rows=100):
        Xs.append(Xc)
        ys.append(yc)
    X = np.concatenate(Xs)
    np.testing.assert_array_equal(np.concatenate(ys), y)
    full = np.concatenate([sa[np.arange(n)], sp[np.arange(n)]], axis=1)
    np.testing.assert_array_equal(np.sort(full, axis=1),
                                  np.sort(X, axis=1))  # column split perm
    # second call with identical params is a no-op (meta matches)
    before = os.path.getmtime(os.path.join(root, "active", "meta.json"))
    write_sharded("credit", root, seed=3, scale=0.01, chunk_rows=100,
                  rows_per_shard=64)
    assert os.path.getmtime(
        os.path.join(root, "active", "meta.json")) == before


# ---------------------------------------------------------------------------
# streaming-vs-resident training parity (bit-for-bit, DP included)
# ---------------------------------------------------------------------------
_RESIDENT = {}


def _resident(method):
    if method not in _RESIDENT:
        _RESIDENT[method] = Session(
            ExperimentConfig(**{**BASE, "method": method})).run()
    return _RESIDENT[method]


@pytest.mark.parametrize("method", ["pubsub", "vfl_ps"])
@pytest.mark.parametrize("wb", [2, 3])   # 3 does not divide the windows
def test_streaming_bitwise_parity(method, wb):
    r0 = _resident(method)
    r1 = Session(ExperimentConfig(
        **{**BASE, "method": method}, **STREAM,
        stream_window_batches=wb)).run()
    assert r1.train.losses == r0.train.losses
    assert r1.train.history == r0.train.history
    assert r1.train.final_metric == r0.train.final_metric
    assert r0.data_path is None
    stats = r1.data_path
    assert stats is not None and stats["backing"] == "wrap"
    assert all(w >= 2 for w in stats["windows_per_epoch"])
    assert stats["peak_staged_bytes"] <= stats["bytes_staged"]


def test_mid_epoch_window_checkpoint_resume_bitwise(tmp_path):
    import jax

    cfg = ExperimentConfig(**BASE, **STREAM, stream_window_batches=4)
    sess = Session(cfg)
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    data = eng.stage_data(t.Xa, t.Xp, t.y,
                          window_batches=t.stream_window_batches)
    assert len(data.stats["windows_per_epoch"]) == cfg.n_epochs
    hy = t.hyper()

    ref = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                         t.d_emb, seed=0)
    for e in range(cfg.n_epochs):
        ref = eng.run_epoch(ref, e, data, hy)

    st = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                        t.d_emb, seed=0)
    st = eng.run_epoch(st, 0, data, hy, max_windows=1)
    assert (int(st.epoch), int(st.window)) == (0, 1)
    path = str(tmp_path / "mid_window.msgpack")
    save_state(path, st, step=0)
    st = eng.load_state(restore_state(path))
    assert int(st.window) == 1            # resumes inside epoch 0
    st = eng.run_epoch(st, 0, data, hy)   # finishes the epoch
    assert (int(st.epoch), int(st.window)) == (1, 0)
    for e in range(1, cfg.n_epochs):
        st = eng.run_epoch(st, e, data, hy)
    for a, b in zip(jax.tree.leaves(ref.carry), jax.tree.leaves(st.carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pre-streaming 10-field payloads (no `window`) still load
    legacy = tuple(list(ref)[:10])
    assert int(eng.load_state(legacy).window) == 0


def test_resident_fallthrough_and_budget_window():
    # a generous budget on a tiny dataset: resident path, no streaming
    sess = Session(ExperimentConfig(**BASE, data_budget_mb=1024.0))
    assert not sess._streaming()
    assert sess.window_batches() is None
    assert not sess.prepare().streaming
    # forced streaming with a budget: window sized from it, and the
    # staged high-water mark (two windows in flight) stays under it
    budget_mb = 0.75
    r = Session(ExperimentConfig(**BASE, **STREAM,
                                 data_budget_mb=budget_mb)).run()
    stats = r.data_path
    assert stats["budget_mb"] == budget_mb
    assert stats["peak_staged_bytes"] <= budget_mb * 1e6
    assert r.train.losses == _resident("pubsub").train.losses


def test_event_engine_accepts_feature_sources():
    cfg = ExperimentConfig(**{**BASE, "n_epochs": 1, "scale": 0.02,
                              "dp_mu": float("inf")}, engine="event")
    r0 = Session(cfg).run()
    r1 = Session(ExperimentConfig(
        **{**BASE, "n_epochs": 1, "scale": 0.02, "dp_mu": float("inf")},
        engine="event", **STREAM)).run()
    assert r1.train.losses == r0.train.losses
    assert r1.train.final_metric == r0.train.final_metric


def test_shards_backing_end_to_end(tmp_path):
    cfg = ExperimentConfig(method="pubsub", dataset="credit", scale=0.01,
                           n_epochs=1, batch_size=32, w_a=2, w_p=2,
                           stream=True, stream_backing="shards",
                           shard_dir=str(tmp_path), stream_chunk_rows=100)
    sess = Session(cfg)
    prep = sess.prepare()
    assert prep.streaming and prep.backing == "shards"
    assert is_feature_source(prep.train_active.X)
    assert not is_feature_source(prep.test_active.X)   # eval is resident
    r = sess.run()
    assert np.isfinite(r.train.final_metric)
    assert r.data_path["backing"] == "shards"
    assert r.data_path["rows_staged"] > 0


# ---------------------------------------------------------------------------
# satellite: background stage-thread failures surface, never hang
# ---------------------------------------------------------------------------
class _FailingSource(ArrayFeatures):
    """Feature source whose reads start failing after `fail_after`
    gathers — a shard file vanishing mid-epoch."""

    def __init__(self, X, fail_after):
        super().__init__(X)
        self.calls = 0
        self.fail_after = fail_after

    def gather(self, rows):
        self.calls += 1
        if self.calls > self.fail_after:
            raise OSError("injected shard read failure")
        return super().gather(rows)

    __getitem__ = gather


def test_background_staging_failure_surfaces_as_staging_error():
    """A read error on the staging thread must propagate out of
    `run_epoch` as `StagingError` naming the window/epoch — not hang the
    consumer loop or leak as an opaque future exception."""
    import pytest

    from repro.core.jit_pipeline import StagingError

    cfg = ExperimentConfig(**BASE, **STREAM, stream_window_batches=2)
    sess = Session(cfg)
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    src = _FailingSource(np.asarray(t.Xa), fail_after=1)
    data = eng.stage_data(src, t.Xp, t.y,
                          window_batches=t.stream_window_batches)
    st = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                        t.d_emb, seed=0)
    with pytest.raises(StagingError, match="background staging"):
        eng.run_epoch(st, 0, data, t.hyper())
    assert src.calls > src.fail_after     # it was the injected failure
