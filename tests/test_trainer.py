"""Trainer-level tests: event replay semantics, staleness, DP plumbing."""
import numpy as np
import pytest

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import RunConfig, simulate
from repro.core.trainer import VFLTrainer, _auc
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split
from repro.dp.gdp import GDPConfig


def setup(method="pubsub", n_epochs=3, **kw):
    ds = load("credit", scale=0.05)
    tr, te = ds.split()
    a_tr, p_tr = vertical_split(tr)
    a_te, p_te = vertical_split(te)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=64, n_epochs=n_epochs, w_a=4, w_p=4,
                    profile=prof)
    sim = simulate(cfg)
    trainer = VFLTrainer(cfg, a_tr, p_tr, a_te, p_te, ds.task, **kw)
    return cfg, sim, trainer


def test_auc_metric():
    y = np.array([0, 0, 1, 1])
    assert _auc(y, np.array([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert _auc(y, np.array([0.9, 0.8, 0.2, 0.1])) == 0.0
    assert 0.4 < _auc(y, np.array([0.5, 0.5, 0.5, 0.5])) < 0.6


def test_replay_converges():
    cfg, sim, trainer = setup()
    res = trainer.replay(sim)
    assert res.final_metric > 0.9
    assert len(res.history) == cfg.n_epochs
    assert res.n_updates > 0


def test_replay_async_has_staleness_sync_does_not():
    _, sim_v, tr_v = setup(method="vfl")
    res_v = tr_v.replay(sim_v)
    assert res_v.staleness_mean == 0.0
    _, sim_p, tr_p = setup(method="pubsub")
    res_p = tr_p.replay(sim_p)
    assert res_p.staleness_mean >= 0.0


def test_dp_noise_applied():
    gdp = GDPConfig(mu=0.05, clip=0.5, minibatch=64, global_batch=64,
                    n_queries=200)
    cfg, sim, trainer = setup(gdp=gdp)
    assert trainer.sigma > 0
    res = trainer.replay(sim)
    cfg2, sim2, clean = setup()
    res2 = clean.replay(sim2)
    # heavy noise should not *beat* the clean run
    assert res.final_metric <= res2.final_metric + 0.02


def test_replica_counts_by_method():
    for method, expect in [("vfl", 1), ("avfl", 1)]:
        _, _, tr = setup(method=method)
        assert tr.n_rep_a == expect and tr.n_rep_p == expect
    _, _, tr = setup(method="vfl_ps")
    assert tr.n_rep_a == tr.n_rep_p == 4
    _, _, tr = setup(method="pubsub")
    assert tr.n_rep_a == 4 and tr.n_rep_p == 4
