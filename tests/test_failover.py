"""Driver checkpoint-failover: a `Watchdog` that kills the driver
mid-run, and `run_with_failover` restoring the latest snapshot and
resuming — proven bit-identical to the uninterrupted run, on healthy
AND fault-injected worlds.  The checkpoint lands before the crash and
`replay_with` appends each epoch to history before callbacks fire, so
recovery loses nothing that was evaluated."""
import math

import pytest

from repro.api import (CrashFault, DriverCrash, ExperimentConfig,
                       FaultPlan, Session, StragglerFault, Watchdog,
                       run_with_failover)
from repro.checkpoint.store import CheckpointCorrupt, restore_state

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=4,
            batch_size=64, w_a=4, w_p=4)

FAULTS = FaultPlan(
    crashes=(CrashFault(side="p", replica=1, at=0.15,
                        rejoin_after=0.2),),
    stragglers=(StragglerFault(side="a", replica=0, factor=2.0,
                               start=0.1, ramp=0.2),))


def _cfg(**kw):
    d = dict(BASE)
    d.update(kw)
    return ExperimentConfig(**d)


def test_watchdog_crash_is_catchable_and_checkpointed(tmp_path):
    path = str(tmp_path / "wd.msgpack")
    wd = Watchdog(path, every=1, crash_at=(2,))
    sess = Session(_cfg())
    with pytest.raises(DriverCrash):
        sess.run(callbacks=[wd])
    # the snapshot landed BEFORE the crash fired
    state = sess.compile().engine.load_state(restore_state(path))
    assert int(state.epoch) == 2
    # each configured crash fires once — a bare retry then completes
    res = sess.run(state=state, callbacks=[wd])
    assert len(res.train.losses) == BASE["n_epochs"]


@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_failover_resume_is_bit_identical(engine, tmp_path):
    cfg = _cfg(engine=engine)
    full = Session(cfg).run()
    wd = Watchdog(str(tmp_path / "wd.msgpack"), every=1, crash_at=(2,))
    res = run_with_failover(Session(cfg), wd)
    # losses cover ALL epochs (per-epoch buckets ride in the state)
    assert res.train.losses == full.train.losses
    # post-recovery history must continue the exact sequence
    assert res.train.history == full.train.history[2:]
    assert res.train.final_metric == full.train.final_metric


def test_failover_through_faulty_world_dp(tmp_path):
    """Driver dies twice while the simulated cluster itself is degraded
    (replica crash + straggler) with DP noise on — recovery must resume
    the exact noise stream and masked-lane schedule."""
    cfg = _cfg(faults=FAULTS, dp_mu=0.5)
    full = Session(cfg).run()
    wd = Watchdog(str(tmp_path / "wd.msgpack"), every=1, crash_at=(1, 3))
    res = run_with_failover(Session(cfg), wd)
    assert res.train.losses == full.train.losses
    assert res.train.final_metric == full.train.final_metric


def test_failover_gives_up_after_max_restarts(tmp_path):
    wd = Watchdog(str(tmp_path / "wd.msgpack"), every=1,
                  crash_at=(1, 2, 3))
    with pytest.raises(DriverCrash):
        run_with_failover(Session(_cfg()), wd, max_restarts=1)


def test_failover_refuses_corrupt_checkpoint(tmp_path):
    """A torn snapshot must surface as CheckpointCorrupt, not resume
    from garbage."""
    path = str(tmp_path / "wd.msgpack")
    wd = Watchdog(path, every=1, crash_at=(2,))
    sess = Session(_cfg())
    with pytest.raises(DriverCrash):
        sess.run(callbacks=[wd])
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[:len(raw) // 2])      # torn write
    wd2 = Watchdog(path, every=math.inf, crash_at=(3,))
    with pytest.raises(CheckpointCorrupt):
        run_with_failover(sess, wd2)
