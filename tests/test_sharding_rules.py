"""Unit tests for the partition-rule logic (no multi-device mesh needed:
rules are pure functions of names/shapes + a 1-device mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.sharding import _fit_divisibility, _spec_for


def test_spec_rules():
    assert _spec_for("wq", 2, stacked=False) == (None, "model")
    assert _spec_for("wo", 2, stacked=False) == ("model", None)
    assert _spec_for("wq", 3, stacked=True) == (None, None, "model")
    assert _spec_for("embed", 2, stacked=False) == ("model", None)
    # MoE expert weights: expert-parallel on the expert dim
    assert _spec_for("wg", 3, stacked=False) == ("model", None, None)
    assert _spec_for("wg", 4, stacked=True) == (None, "model", None, None)
    # norms and other vectors replicate
    assert _spec_for("n1", 1, stacked=False) == (None,)
    assert _spec_for("bq", 1, stacked=False) == ("model",)


def test_divisibility_guard():
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16}
    # 504-way head (hubert) must stay replicated on a 16-way axis
    spec = _fit_divisibility((None, "model"), (1280, 504), FakeMesh())
    assert spec == P(None, None)
    spec = _fit_divisibility((None, "model"), (1280, 512), FakeMesh())
    assert spec == P(None, "model")


def test_all_arch_params_get_valid_specs():
    """Every assigned arch's param tree maps to divisible specs on a
    16-way model axis (the single-pod production mesh)."""
    import jax.numpy as jnp
    from repro.configs import ASSIGNED, get_config
    from repro.launch.sharding import params_sharding
    from repro.models.transformer import SplitModel

    class FakeMesh:
        shape = {"model": 16, "data": 16}

    # NamedSharding construction needs a real mesh; test the spec layer by
    # monkeypatching NamedSharding to capture specs
    import repro.launch.sharding as sh
    captured = []
    orig = sh.NamedSharding
    sh.NamedSharding = lambda mesh, spec: captured.append(spec) or spec
    try:
        for arch in ASSIGNED:
            cfg = get_config(arch)
            model = SplitModel(cfg)
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            params_sharding(shapes, FakeMesh())
    finally:
        sh.NamedSharding = orig
    assert len(captured) > 100          # all leaves visited
