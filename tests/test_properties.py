"""Hypothesis property-based tests on system invariants.

`hypothesis` is an optional dev dependency (see requirements-dev.txt);
the whole module is skipped when it is not installed so the tier-1 run
does not die at collection."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.channels import Channel, Message
from repro.core.cost_model import CostModel, PartyProfile, SystemProfile
from repro.core.profiler import fit_power_law
from repro.core.semi_async import delta_t
from repro.kernels.rglru_scan.ref import (rglru_scan_assoc_ref,
                                          rglru_scan_ref)
from repro.models.common import cross_entropy

SET = dict(max_examples=25, deadline=None)


@settings(**SET)
@given(cap=st.integers(1, 8), n=st.integers(0, 30))
def test_channel_capacity_invariant(cap, n):
    """Buffer never exceeds capacity; surviving entries are the newest."""
    ch = Channel(capacity=cap)
    for i in range(n):
        ch.publish(Message(i, i, float(i)))
    assert len(ch) == min(cap, n)
    ids = [m.batch_id for m in ch.buf]
    assert ids == list(range(max(0, n - cap), n))
    assert ch.n_evicted == max(0, n - cap)


@settings(**SET)
@given(dt0=st.integers(1, 40), t=st.integers(0, 200))
def test_delta_t_bounds(dt0, t):
    v = delta_t(t, dt0)
    assert 1 <= v <= dt0
    assert delta_t(t + 1, dt0) >= v                # monotone


@settings(**SET)
@given(B=st.integers(1, 3), S=st.integers(1, 24), W=st.integers(1, 12),
       seed=st.integers(0, 2**16))
def test_rglru_assoc_equals_sequential(B, S, W, seed):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(k[0], (B, S, W)))
    u = jax.random.normal(k[1], (B, S, W))
    h0 = jax.random.normal(k[2], (B, W))
    h1, l1 = rglru_scan_ref(a, u, h0)
    h2, l2 = rglru_scan_assoc_ref(a, u, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@settings(**SET)
@given(lam=st.floats(1e-4, 1.0), gam=st.floats(-1.4, 0.2))
def test_fit_power_law_inverts(lam, gam):
    B = np.array([8, 16, 32, 64, 128, 256, 512])
    t = lam * B ** (1 + gam)
    lam2, gam2 = fit_power_law(B, t)
    assert math.isclose(lam2, lam, rel_tol=1e-4)
    assert math.isclose(gam2, gam, rel_tol=1e-3, abs_tol=1e-4)


@settings(**SET)
@given(ca=st.integers(2, 64), cp=st.integers(2, 64),
       wa=st.integers(1, 16), wp=st.integers(1, 16),
       B=st.sampled_from([16, 64, 256, 1024]))
def test_cost_model_positive_and_monotone_in_cores(ca, cp, wa, wp, B):
    cm1 = CostModel(SystemProfile(active=PartyProfile(cores=ca),
                                  passive=PartyProfile(cores=cp)))
    cm2 = CostModel(SystemProfile(active=PartyProfile(cores=2 * ca),
                                  passive=PartyProfile(cores=2 * cp)))
    o1 = cm1.objective(wa, wp, B)
    o2 = cm2.objective(wa, wp, B)
    assert o1 > 0
    assert o2 < o1                                 # more cores never hurts


@settings(**SET)
@given(B=st.integers(1, 4), S=st.integers(2, 10), V=st.integers(2, 30),
       seed=st.integers(0, 2**16))
def test_cross_entropy_matches_manual(B, S, V, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(k1, (B, S, V))
    labels = jax.random.randint(k2, (B, S), 0, V)
    ce = float(cross_entropy(logits, labels))
    logp = jax.nn.log_softmax(logits, axis=-1)
    manual = -float(jnp.take_along_axis(
        logp, labels[..., None], axis=-1).mean())
    assert math.isclose(ce, manual, rel_tol=1e-5, abs_tol=1e-5)


# --- mesh lowering invariants ------------------------------------------
#
# device_lower must be a pure re-labelling of the replica axis: the
# engine-order op stream (tick, phase, replica, batch, ring slots) has
# to survive the lane permutation exactly, slot and bid arrays (and so
# ring-slot lifetimes) must be byte-identical, and the slab plan has to
# balance real lanes within one per device for any (replicas, devices).

from repro.core.des import RunConfig, simulate                # noqa: E402
from repro.core.schedule import (compile_schedule, device_lower,  # noqa: E402
                                 slab_plan)


@settings(**SET)
@given(n_real=st.integers(1, 24), n_devices=st.integers(1, 8))
def test_slab_plan_invariants(n_real, n_devices):
    p = slab_plan(n_real, n_devices)
    assert p.n_lanes == n_devices * p.lanes_per_device >= n_real
    # lane_of is injective and rep_of inverts it; everything else pads
    assert len(set(p.lane_of)) == n_real
    for r, lane in enumerate(p.lane_of):
        assert p.rep_of[lane] == r
    assert sum(1 for r in p.rep_of if r < 0) == p.n_lanes - n_real
    load = p.device_load
    assert sum(load) == n_real
    assert max(load) - min(load) <= 1


def _sched(method, n_rep, B, jitter, pack):
    prof = SystemProfile(active=PartyProfile(cores=16),
                         passive=PartyProfile(cores=16))
    cfg = RunConfig(method=method, n_samples=4 * B, batch_size=B,
                    n_epochs=2, w_a=n_rep, w_p=n_rep, profile=prof,
                    jitter=jitter)
    return compile_schedule(cfg, simulate(cfg).events, n_rep_a=n_rep,
                            n_rep_p=n_rep, n_samples=4 * B, pack=pack)


def _op_stream(sched, rep_of_a=None, rep_of_p=None):
    """Engine decode order, lanes mapped back to replicas."""
    def conv(ph, lane):
        m = rep_of_a if ph == "as" else rep_of_p
        r = lane if m is None else m[lane]
        assert r >= 0, f"work row on a padding lane: {ph} lane {lane}"
        return r

    def emit(tick0, t, ph, arrays, out):
        rep, bid = arrays[f"{ph}_rep"], arrays[f"{ph}_bid"]
        for j in range(rep.shape[1]):
            if int(rep[t, j]) < 0:
                continue
            slots = ((int(arrays["as_eslot"][t, j]),
                      int(arrays["as_gslot"][t, j])) if ph == "as"
                     else (int(arrays[f"{ph}_slot"][t, j]),))
            out.append((tick0 + t, ph, conv(ph, int(rep[t, j])),
                        int(bid[t, j]), slots))

    out, tick0 = [], 0
    for seg in sched.segments:
        runs = seg.runs if hasattr(seg, "runs") else [seg]
        for run in runs:
            arrays = run.arrays if hasattr(run, "arrays") else {
                k: getattr(run, k) for k in
                ("pf_rep", "pf_bid", "pf_slot", "pb_rep", "pb_bid",
                 "pb_slot", "as_rep", "as_bid", "as_eslot", "as_gslot")}
            sig = run.sig if hasattr(run, "sig") else ("pf", "pb", "as")
            T = (run.n_ticks if hasattr(run, "n_ticks")
                 else int(arrays["pf_rep"].shape[0]))
            for t in range(T):
                for ph in ("pb", "pf", "as"):      # engine phase order
                    if ph in sig:
                        emit(tick0, t, ph, arrays, out)
            tick0 += T
    return out


@settings(**SET)
@given(method=st.sampled_from(["pubsub", "vfl_ps"]),
       n_rep=st.integers(2, 6), n_devices=st.sampled_from([2, 3, 4]),
       B=st.sampled_from([32, 64]), jitter=st.floats(0.0, 0.3),
       pack=st.sampled_from(["packed", "segmented"]))
def test_device_lower_is_pure_relabelling(method, n_rep, n_devices, B,
                                          jitter, pack):
    sched = _sched(method, n_rep, B, jitter, pack)
    low = device_lower(sched, n_devices)
    pa, pp = low.slab_a, low.slab_p
    assert max(pa.device_load) - min(pa.device_load) <= 1
    assert max(pp.device_load) - min(pp.device_load) <= 1
    assert low.n_rep_a % n_devices == 0
    assert low.n_rep_p % n_devices == 0
    # decode order survives the lane map exactly, slots and bids intact
    assert _op_stream(low, pa.rep_of, pp.rep_of) == _op_stream(sched)
    # ring-slot lifetimes are layout-invariant: every non-rep array is
    # byte-identical between the original and the lowered schedule
    for s, l in zip(sched.segments, low.segments):
        s_runs = s.runs if hasattr(s, "runs") else [s]
        l_runs = l.runs if hasattr(l, "runs") else [l]
        assert len(s_runs) == len(l_runs)
        for sr, lr in zip(s_runs, l_runs):
            if hasattr(sr, "arrays"):
                assert sr.sig == lr.sig
                for k, v in sr.arrays.items():
                    if not k.endswith("_rep"):
                        assert np.array_equal(v, lr.arrays[k]), k
            else:
                for k in ("pf_bid", "pf_slot", "pb_bid", "pb_slot",
                          "as_bid", "as_eslot", "as_gslot", "as_epoch",
                          "agg_a", "agg_p"):
                    assert np.array_equal(getattr(sr, k),
                                          getattr(lr, k)), k


@settings(**SET)
@given(seed=st.integers(0, 2**16), sigma=st.floats(0.0, 2.0))
def test_cut_layer_dp_noise_distribution(seed, sigma):
    """Noise added by the cut layer has the configured scale."""
    from repro.kernels.cut_layer.ref import cut_layer_ref
    k = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jnp.zeros((64, 8))
    w = jnp.zeros((8, 16))
    b = jnp.zeros((16,))
    nz = jax.random.normal(k[0], (64, 16))
    out = cut_layer_ref(x, w, b, nz, clip=1.0, sigma=sigma)
    np.testing.assert_allclose(np.asarray(out), sigma * np.asarray(nz),
                               atol=1e-6)
