"""Hypothesis property-based tests on system invariants.

`hypothesis` is an optional dev dependency (see requirements-dev.txt);
the whole module is skipped when it is not installed so the tier-1 run
does not die at collection."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.channels import Channel, Message
from repro.core.cost_model import CostModel, PartyProfile, SystemProfile
from repro.core.profiler import fit_power_law
from repro.core.semi_async import delta_t
from repro.kernels.rglru_scan.ref import (rglru_scan_assoc_ref,
                                          rglru_scan_ref)
from repro.models.common import cross_entropy

SET = dict(max_examples=25, deadline=None)


@settings(**SET)
@given(cap=st.integers(1, 8), n=st.integers(0, 30))
def test_channel_capacity_invariant(cap, n):
    """Buffer never exceeds capacity; surviving entries are the newest."""
    ch = Channel(capacity=cap)
    for i in range(n):
        ch.publish(Message(i, i, float(i)))
    assert len(ch) == min(cap, n)
    ids = [m.batch_id for m in ch.buf]
    assert ids == list(range(max(0, n - cap), n))
    assert ch.n_evicted == max(0, n - cap)


@settings(**SET)
@given(dt0=st.integers(1, 40), t=st.integers(0, 200))
def test_delta_t_bounds(dt0, t):
    v = delta_t(t, dt0)
    assert 1 <= v <= dt0
    assert delta_t(t + 1, dt0) >= v                # monotone


@settings(**SET)
@given(B=st.integers(1, 3), S=st.integers(1, 24), W=st.integers(1, 12),
       seed=st.integers(0, 2**16))
def test_rglru_assoc_equals_sequential(B, S, W, seed):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(k[0], (B, S, W)))
    u = jax.random.normal(k[1], (B, S, W))
    h0 = jax.random.normal(k[2], (B, W))
    h1, l1 = rglru_scan_ref(a, u, h0)
    h2, l2 = rglru_scan_assoc_ref(a, u, h0)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@settings(**SET)
@given(lam=st.floats(1e-4, 1.0), gam=st.floats(-1.4, 0.2))
def test_fit_power_law_inverts(lam, gam):
    B = np.array([8, 16, 32, 64, 128, 256, 512])
    t = lam * B ** (1 + gam)
    lam2, gam2 = fit_power_law(B, t)
    assert math.isclose(lam2, lam, rel_tol=1e-4)
    assert math.isclose(gam2, gam, rel_tol=1e-3, abs_tol=1e-4)


@settings(**SET)
@given(ca=st.integers(2, 64), cp=st.integers(2, 64),
       wa=st.integers(1, 16), wp=st.integers(1, 16),
       B=st.sampled_from([16, 64, 256, 1024]))
def test_cost_model_positive_and_monotone_in_cores(ca, cp, wa, wp, B):
    cm1 = CostModel(SystemProfile(active=PartyProfile(cores=ca),
                                  passive=PartyProfile(cores=cp)))
    cm2 = CostModel(SystemProfile(active=PartyProfile(cores=2 * ca),
                                  passive=PartyProfile(cores=2 * cp)))
    o1 = cm1.objective(wa, wp, B)
    o2 = cm2.objective(wa, wp, B)
    assert o1 > 0
    assert o2 < o1                                 # more cores never hurts


@settings(**SET)
@given(B=st.integers(1, 4), S=st.integers(2, 10), V=st.integers(2, 30),
       seed=st.integers(0, 2**16))
def test_cross_entropy_matches_manual(B, S, V, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(k1, (B, S, V))
    labels = jax.random.randint(k2, (B, S), 0, V)
    ce = float(cross_entropy(logits, labels))
    logp = jax.nn.log_softmax(logits, axis=-1)
    manual = -float(jnp.take_along_axis(
        logp, labels[..., None], axis=-1).mean())
    assert math.isclose(ce, manual, rel_tol=1e-5, abs_tol=1e-5)


@settings(**SET)
@given(seed=st.integers(0, 2**16), sigma=st.floats(0.0, 2.0))
def test_cut_layer_dp_noise_distribution(seed, sigma):
    """Noise added by the cut layer has the configured scale."""
    from repro.kernels.cut_layer.ref import cut_layer_ref
    k = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jnp.zeros((64, 8))
    w = jnp.zeros((8, 16))
    b = jnp.zeros((16,))
    nz = jax.random.normal(k[0], (64, 16))
    out = cut_layer_ref(x, w, b, nz, clip=1.0, sigma=sigma)
    np.testing.assert_allclose(np.asarray(out), sigma * np.asarray(nz),
                               atol=1e-6)
