"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU asserting output shapes + no NaNs) and cache-consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicability
from repro.configs.base import ArchConfig
from repro.models.transformer import SplitModel, split_stages
from repro.launch.steps import make_train_step


def make_batch(cfg: ArchConfig, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.frontend == "audio_frames":
        batch["tokens_p"] = jax.random.normal(key, (B, S, cfg.d_model))
        S_total = S
    elif cfg.frontend == "vision_patches":
        n_vis = max(1, S // 4)
        batch["tokens_p"] = jax.random.randint(key, (B, S - n_vis), 0,
                                               cfg.vocab_size)
        batch["patches_p"] = jax.random.normal(key, (B, n_vis, cfg.d_model))
        S_total = S
    else:
        batch["tokens_p"] = jax.random.randint(key, (B, S), 0,
                                               cfg.vocab_size)
        S_total = S
    batch["x_a"] = jax.random.normal(key, (B, S_total, cfg.d_active))
    lab_len = (batch["tokens_p"].shape[1]
               if cfg.frontend != "audio_frames" else S)
    batch["labels"] = jax.random.randint(key, (B, lab_len), 0,
                                         cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_loss(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, _, aux = model.forward(params, batch)
    B = batch["x_a"].shape[0]
    S_total = batch["x_a"].shape[1]
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-1.6b",
                                  "recurrentgemma-9b"])
def test_smoke_train_step(arch):
    """One real optimizer step decreases nothing NaN-wise and changes
    params."""
    cfg = get_config(arch).reduced()
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt, step = make_train_step(model, lr=1e-3)
    opt_state = opt.init(params)
    batch = make_batch(cfg)
    p2, opt_state, loss = jax.jit(step)(params, opt_state, batch,
                                        jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))
    diff = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert diff > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v2-lite-16b",
                                  "rwkv6-1.6b", "recurrentgemma-9b"])
def test_decode_matches_parallel_forward(arch):
    """Token-by-token decode with cache == full forward logits."""
    cfg = get_config(arch).reduced()
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    xa = jax.random.normal(key, (B, S, cfg.d_active))
    full_logits, _, _ = model.forward(params,
                                      {"tokens_p": toks, "x_a": xa})
    cache = model.init_cache(B, S)
    step_logits = []
    for t in range(S):
        lg, cache = model.decode_step(
            params, {"tokens_p": toks[:, t:t + 1],
                     "x_a": xa[:, t:t + 1]}, cache)
        step_logits.append(lg)
    dec = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-2, rtol=2e-2)


def test_decode_window_ring_buffer():
    """Sliding-window decode: ring cache gives same logits as a full cache
    once the window covers the whole history."""
    cfg = get_config("qwen2-0.5b").reduced().replace(sliding_window=16)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12   # S < window: ring == full
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    xa = jnp.zeros((B, S, cfg.d_active))
    cache = model.init_cache(B, 64)       # attn caches capped to window=16
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(
            params, {"tokens_p": toks[:, t:t + 1],
                     "x_a": xa[:, t:t + 1]}, cache)
        outs.append(lg)
    full_cfg = cfg.replace(sliding_window=None)
    m2 = SplitModel(full_cfg)
    full_logits, _, _ = m2.forward(params, {"tokens_p": toks, "x_a": xa})
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full_logits), atol=2e-2,
                               rtol=2e-2)


def test_split_stages_preserves_layer_count():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        bottom, top = split_stages(cfg.resolved_stages, cfg.resolved_cut)
        n = sum(r * len(p) for r, p in bottom) + \
            sum(r * len(p) for r, p in top)
        assert n == cfg.n_layers, arch
        assert bottom and top


def test_shape_applicability_rules():
    hubert = get_config("hubert-xlarge")
    assert shape_applicability(hubert, SHAPES["decode_32k"])[0] is False
    assert shape_applicability(hubert, SHAPES["train_4k"])[0] is True
    rwkv = get_config("rwkv6-1.6b")
    ok, note = shape_applicability(rwkv, SHAPES["long_500k"])
    assert ok and note == ""
    dense = get_config("qwen2.5-14b")
    ok, note = shape_applicability(dense, SHAPES["long_500k"])
    assert ok and "sliding-window" in note


def test_param_count_plausible():
    # full configs should land within ~35% of the nameplate sizes
    approx = {
        "qwen2.5-14b": 14e9, "minitron-8b": 8e9, "phi4-mini-3.8b": 3.8e9,
        "qwen2-0.5b": 0.5e9, "rwkv6-1.6b": 1.6e9,
        "recurrentgemma-9b": 9e9, "deepseek-v2-lite-16b": 16e9,
        "qwen3-moe-30b-a3b": 30e9,
    }
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert 0.5 * target < n < 1.6 * target, (name, n, target)
