"""EIA attack harness + GDP end-to-end properties."""
import jax
import numpy as np

from repro.data.synthetic import load
from repro.data.vertical import vertical_split
from repro.dp.eia import attack_success_rate, fit_inverter, run_eia
from repro.models import tabular


def test_inverter_recovers_linear_embedding():
    rng = np.random.default_rng(0)
    W_true = rng.normal(size=(24, 32))
    X = rng.normal(size=(500, 24)).astype(np.float32)
    Z = X @ W_true                       # overcomplete linear embedding
    W = fit_inverter(Z[:250].astype(np.float32), X[:250])
    asr = attack_success_rate(Z[250:].astype(np.float32), X[250:], W,
                              threshold=0.5)
    assert asr > 0.8                     # linear embeddings leak


def test_gdp_noise_kills_attack():
    ds = load("credit", scale=0.05)
    _, passive = vertical_split(ds)
    theta = tabular.init_bottom(jax.random.PRNGKey(0), passive.X.shape[1])
    X = passive.X[:1500]
    asr_clean = run_eia(tabular.passive_forward, theta, X, sigma=0.0,
                        clip=1.0, threshold=0.3)
    asr_noisy = run_eia(tabular.passive_forward, theta, X, sigma=20.0,
                        clip=1.0, threshold=0.3)
    assert asr_noisy < asr_clean         # Fig. 5 direction
    assert asr_noisy < 0.5 * asr_clean + 0.05
