"""Per-kernel allclose validation: Pallas (interpret=True) vs pure-jnp
oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import (rglru_scan_assoc_ref,
                                          rglru_scan_ref)
from repro.kernels.cut_layer.kernel import cut_layer_pallas
from repro.kernels.cut_layer.ref import cut_layer_ref
from repro.models.attention import blockwise_sdpa, sdpa


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,Hq,Hk,D", [
    (1, 32, 2, 2, 8),       # MHA
    (2, 64, 4, 2, 16),      # GQA
    (1, 128, 8, 1, 32),     # MQA
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 16), (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, Hk, D, causal, window, dtype):
    kq, kk, kv = keys(3)
    q = jax.random.normal(kq, (B, S, Hq, D), dtype)
    k = jax.random.normal(kk, (B, S, Hk, D), dtype)
    v = jax.random.normal(kv, (B, S, Hk, D), dtype)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=16, block_k=16, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_model_sdpa():
    kq, kk, kv = keys(3, 7)
    q = jax.random.normal(kq, (2, 64, 4, 16))
    k = jax.random.normal(kk, (2, 64, 2, 16))
    v = jax.random.normal(kv, (2, 64, 2, 16))
    a = sdpa(q, k, v, causal=True, window=None, q_offset=0)
    b = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                               block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_blockwise_sdpa_matches_dense():
    kq, kk, kv = keys(3, 3)
    q = jax.random.normal(kq, (1, 2048, 2, 8))
    k = jax.random.normal(kk, (1, 2048, 1, 8))
    v = jax.random.normal(kv, (1, 2048, 1, 8))
    for causal, window in [(True, None), (True, 512), (False, None)]:
        a = sdpa(q, k, v, causal=causal, window=window, q_offset=0)
        b = blockwise_sdpa(q, k, v, causal=causal, window=window,
                           q_offset=0, q_chunk=256, kv_chunk=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,D", [(1, 8, 1, 4), (2, 32, 3, 8),
                                     (1, 64, 2, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(B, S, H, D, dtype):
    ks = keys(6, 1)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, D), dtype)
               for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D))).astype(dtype)
    u = jax.random.normal(ks[4], (H, D), dtype)
    s0 = jax.random.normal(ks[5], (B, H, D, D), jnp.float32)
    y1, f1 = rwkv6_scan_ref(r, k, v, w, u, s0)
    y2, f2 = rwkv6_scan_pallas(r, k, v, w, u, s0, block_t=8,
                               interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-3)


def test_rwkv6_chunked_equals_unchunked():
    ks = keys(6, 2)
    B, S, H, D = 1, 32, 2, 8
    r, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, D)))
    u = jax.random.normal(ks[4], (H, D))
    s0 = jnp.zeros((B, H, D, D))
    y_a, f_a = rwkv6_scan_pallas(r, k, v, w, u, s0, block_t=32,
                                 interpret=True)
    y_b, f_b = rwkv6_scan_pallas(r, k, v, w, u, s0, block_t=8,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_a), np.asarray(f_b), atol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,W", [(1, 16, 8), (2, 64, 32), (3, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, W, dtype):
    ks = keys(3, 4)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    u = jax.random.normal(ks[1], (B, S, W), dtype)
    h0 = jax.random.normal(ks[2], (B, W), jnp.float32)
    h1, l1 = rglru_scan_ref(a, u, h0)
    h2, l2 = rglru_scan_assoc_ref(a, u, h0)
    h3, l3 = rglru_scan_pallas(a, u, h0, block_t=8, block_w=8,
                               interpret=True)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h3, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l3), atol=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [(16, 32, 8), (64, 96, 48),
                                   (128, 64, 128)])
@pytest.mark.parametrize("sigma", [0.0, 0.5])
def test_cut_layer(M, K, N, sigma):
    ks = keys(4, 5)
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.1
    b = jax.random.normal(ks[2], (N,)) * 0.1
    nz = jax.random.normal(ks[3], (M, N))
    ref = cut_layer_ref(x, w, b, nz, clip=1.0, sigma=sigma)
    out = cut_layer_pallas(x, w, b, nz, clip=1.0, sigma=sigma,
                           block_m=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("M,K,N", [(16, 32, 8), (64, 96, 48),
                                   (128, 64, 128)])
@pytest.mark.parametrize("sigma", [0.0, 0.5])
def test_cut_layer_residual(M, K, N, sigma):
    """Residual ("large model") variant: the skip input is added after
    the tanh, before the L2 clip — kernel vs ref in interpret mode."""
    ks = keys(5, 9)
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.1
    b = jax.random.normal(ks[2], (N,)) * 0.1
    nz = jax.random.normal(ks[3], (M, N))
    r = jax.random.normal(ks[4], (M, N))
    ref = cut_layer_ref(x, w, b, nz, clip=1.0, sigma=sigma, residual=r)
    out = cut_layer_pallas(x, w, b, nz, r, clip=1.0, sigma=sigma,
                           block_m=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # and the residual really participates: differs from the plain path
    plain = cut_layer_ref(x, w, b, nz, clip=1.0, sigma=sigma)
    assert np.abs(np.asarray(out) - np.asarray(plain)).max() > 1e-3


def test_cut_layer_clip_bounds_norm():
    """Post-clip pre-noise rows have L2 norm <= clip (DP sensitivity)."""
    ks = keys(3, 6)
    x = jax.random.normal(ks[0], (32, 16)) * 5
    w = jax.random.normal(ks[1], (16, 8))
    b = jnp.zeros((8,))
    z = cut_layer_ref(x, w, b, jnp.zeros((32, 8)), clip=0.7, sigma=0.0)
    norms = np.linalg.norm(np.asarray(z), axis=-1)
    assert (norms <= 0.7 + 1e-5).all()
