"""Point-stacked sweeps: `run_sweep(stacked=True)` fuses a structural
group into one vmapped device program and must reproduce the sequential
warm path per point — bit-for-bit parameter trajectories (history and
finals) for non-DP runs, with independent deterministic per-point DP
noise streams.  The per-epoch loss *telemetry* is accumulated by a
device scatter-add whose lane ordering may differ under vmap, so losses
are compared to f32-accumulation tolerance (they are usually bitwise
too)."""
import numpy as np
import pytest

from repro.api import (ExperimentConfig, Session, compile_stats,
                       reset_compile_cache, run_sweep)

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=3,
            batch_size=64, w_a=4, w_p=4)


def _cfgs(n=3, **kw):
    d = dict(BASE)
    d.update(kw)
    return [ExperimentConfig(**d, seed=s) for s in range(n)]


def _assert_point_parity(seq, st):
    for a, b in zip(seq, st):
        assert a.train.history == b.train.history      # bit-for-bit
        assert a["final"] == b["final"]
        np.testing.assert_allclose(a.train.losses, b.train.losses,
                                   rtol=1e-6)
        assert a.seed == b.seed and a.lr == b.lr


def test_stacked_matches_sequential():
    """Whole-group single vmapped program (stack_chunk pins it — the
    CPU default tiles into per-point chunks) reproduces the sequential
    warm path per point."""
    reset_compile_cache()
    cfgs = _cfgs(3)
    seq = run_sweep(cfgs)
    st = run_sweep(cfgs, stacked=True, stack_chunk=3)
    _assert_point_parity(seq, st)
    # the stacked sweep reused the program the sequential sweep compiled
    assert seq.stats["compiles"] == 1
    assert st.stats["compiles"] == 0
    assert st.stats["stacked_groups"] == 1
    assert st.stats["points_per_group"] == [3]
    # sequential mode reports composition too (but stacks nothing)
    assert seq.stats["points_per_group"] == [3]
    assert seq.stats["stacked_groups"] == 0


def test_stacked_mixed_groups_and_singletons():
    """Two structural groups (different batch sizes) plus per-group
    singletons: multi-point groups stack, singletons run sequentially,
    and result order follows the input configs."""
    reset_compile_cache()
    cfgs = _cfgs(2) + _cfgs(1, batch_size=32)
    st = run_sweep(cfgs, stacked=True, stack_chunk=2)
    assert [r.seed for r in st] == [0, 1, 0]
    assert sorted(st.stats["points_per_group"]) == [1, 2]
    assert st.stats["stacked_groups"] == 1
    assert st.stats["n_points"] == 3
    seq = run_sweep(cfgs)
    _assert_point_parity(seq, st)


def test_stacked_lr_sweep_vectors():
    """Same-seed points varying only lr: one group, per-point lr vectors
    reach the vmapped optimizer (finals must differ across lr and match
    the sequential path)."""
    reset_compile_cache()
    base = dict(BASE, n_epochs=2)
    cfgs = [ExperimentConfig(**base, seed=0, lr=lr)
            for lr in (1e-3, 1e-2)]
    seq = run_sweep(cfgs)
    st = run_sweep(cfgs, stacked=True, stack_chunk=2)
    _assert_point_parity(seq, st)
    assert st[0].train.losses != st[1].train.losses
    assert st.stats["stacked_groups"] == 1
    # the platform-default chunking (per-point chunks on CPU) must
    # produce identical results too
    st_default = run_sweep(cfgs, stacked=True)
    _assert_point_parity(seq, st_default)


def test_stacked_dp_noise_independent_and_deterministic():
    """DP under stacking: per-point noise keys are independent (same
    data + params with different seeds diverge) and deterministic (the
    same stacked sweep twice is identical)."""
    reset_compile_cache()
    cfgs = _cfgs(2, n_epochs=2, dp_mu=0.5)
    s1 = run_sweep(cfgs, stacked=True, stack_chunk=2)
    s2 = run_sweep(cfgs, stacked=True, stack_chunk=2)
    for a, b in zip(s1, s2):
        assert a.train.losses == b.train.losses
        assert a.train.history == b.train.history
    seq = run_sweep(cfgs)
    _assert_point_parity(seq, s1)

    # engine-level: identical data/params, different noise seeds — the
    # per-point streams must differ (independent jax.random keys)
    sess = Session(cfgs[0], reuse="structural")
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    data = eng.stage_data_stacked([(t.Xa, t.Xp, t.y)] * 2)
    state = eng.init_state_stacked(
        [(t.theta_a, t.opt_a, t.theta_p, t.opt_p)] * 2, t.d_emb,
        seeds=[0, 1])
    hyper = {k: [t.hyper()[k]] * 2 for k in ("lr", "clip", "sigma")}
    state = eng.run_epoch_stacked(state, 0, data, hyper)
    l0 = np.asarray(eng.point_state(state, 0).loss_vec)
    l1 = np.asarray(eng.point_state(state, 1).loss_vec)
    assert not np.array_equal(l0, l1)


def test_stacked_requires_structural_reuse():
    with pytest.raises(ValueError, match="structural"):
        run_sweep(_cfgs(2), stacked=True, reuse="exact")


def test_stacked_callbacks_fall_back_to_sequential():
    """Per-epoch callbacks are a per-run surface: with callbacks the
    sweep runs sequentially (correct results, nothing stacked)."""
    reset_compile_cache()
    seen = []
    st = run_sweep(_cfgs(2), stacked=True,
                   callbacks=[lambda ctx: seen.append(ctx.epoch)])
    assert st.stats["stacked_groups"] == 0
    assert len(seen) == 2 * BASE["n_epochs"]


def test_scatter_replicas_drop_matches_where_merge():
    """The donation-aliased ``.at[].set(mode="drop")`` scatter variant is
    numerically identical to the default where-merge (masked lanes
    dropped, unreferenced replicas untouched)."""
    import jax.numpy as jnp

    from repro.optim.optimizers import scatter_replicas

    rng = np.random.default_rng(0)
    stack = {"w": jnp.asarray(rng.normal(size=(5, 3, 4)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)}
    lanes = {"w": jnp.asarray(rng.normal(size=(3, 3, 4)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)}
    rep = jnp.asarray([2, -1, 0])
    mask = jnp.asarray([True, False, True])
    where = scatter_replicas(stack, lanes, rep, mask)
    drop = scatter_replicas(stack, lanes, rep, mask, drop=True)
    for k in stack:
        np.testing.assert_array_equal(np.asarray(where[k]),
                                      np.asarray(drop[k]))
    # masked-out lane 1 and unreferenced replicas 1,3,4 stay untouched
    np.testing.assert_array_equal(np.asarray(drop["w"][1]),
                                  np.asarray(stack["w"][1]))
    np.testing.assert_array_equal(np.asarray(drop["w"][2]),
                                  np.asarray(lanes["w"][0]))


def test_stack_unstack_roundtrip():
    """`stack_points`/`point_state` round-trip the full TrainerState."""
    import jax
    from repro.core.engines import point_state, stack_points

    reset_compile_cache()
    sess = Session(_cfgs(1)[0], reuse="structural")
    eng = sess.compile().engine
    t = sess._make_trainer(*sess._resolve_point(None, None, None))
    states = [eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                             t.d_emb, seed=s) for s in (0, 7)]
    stacked = stack_points(states)
    for i, ref in enumerate(states):
        got = point_state(stacked, i)
        assert got.epoch == ref.epoch
        for leaf_g, leaf_r in zip(jax.tree.leaves(got),
                                  jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(leaf_g),
                                          np.asarray(leaf_r))
