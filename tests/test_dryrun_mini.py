"""Mini multi-pod dry-run as a test: 8 forced host devices, (2,2,2) mesh,
reduced configs — proves the sharding rules + lower + compile pipeline in
CI without the 512-device sweep.  Runs in a subprocess because jax locks
the device count at first init."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_config, input_specs
from repro.configs.base import ShapeConfig
from repro.launch.sharding import (batch_sharding, cache_sharding,
                                   params_sharding)
from repro.launch.steps import make_decode_step, make_model, make_train_step

arch = "__ARCH__"
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
data_axes = ("pod", "data")
cfg = get_config(arch).reduced().replace(remat=True)
model = make_model(cfg)
shape = ShapeConfig("mini", seq_len=16, global_batch=8, kind="__KIND__")
specs = input_specs(cfg, shape)
params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_shard = params_sharding(params_shapes, mesh, data_axes=data_axes)
b_shard = batch_sharding(specs, mesh, data_axes=data_axes)
with mesh:
    if shape.kind == "train":
        opt, step = make_train_step(model)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        o_shard = params_sharding(opt_shapes, mesh, zero=True,
                                  data_axes=data_axes)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard, None),
                     out_shardings=(p_shard, o_shard, None))
        compiled = fn.lower(params_shapes, opt_shapes, specs, rng).compile()
    else:
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_shard = cache_sharding(cache_shapes, mesh, data_axes=data_axes)
        fn = jax.jit(make_decode_step(model),
                     in_shardings=(p_shard, b_shard, c_shard),
                     out_shardings=(None, c_shard))
        compiled = fn.lower(params_shapes, specs, cache_shapes).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):      # jax<=0.4.x returns [dict]
    ca = ca[0] if ca else {}
print(json.dumps({"ok": True, "flops": float(dict(ca).get("flops", 0))}))
"""


def _run(arch: str, kind: str):
    env = dict(os.environ, PYTHONPATH=SRC)
    code = SCRIPT.replace("__ARCH__", arch).replace("__KIND__", kind)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0
    return rec


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v2-lite-16b",
                                  "rwkv6-1.6b"])
def test_mini_multipod_train(arch):
    _run(arch, "train")


def test_mini_multipod_decode():
    _run("recurrentgemma-9b", "decode")
