"""Continuous-batching split-inference serving (src/repro/serve/).

Pins the subsystem's three contracts:
  * slot parity — a request's token stream is bit-for-bit independent of
    which slot it lands in, how full the batch is, and what traffic
    shares the batch (KV-cache arch AND recurrent-cache archs);
  * one compiled decode program per (arch, slot_count, cache_cap), with
    sampling params as runtime scalars (temperature never recompiles);
  * prefill consumes the real prompt (golden greedy pin for a fixed
    seed — the pre-subsystem driver fed fresh random tokens instead).
"""
import sys

import numpy as np
import pytest

from repro.serve import (Request, RequestQueue, ServeEngine, SlotRing,
                         open_loop, reference_decode, synthetic_requests)


def make_requests(vocab, n, *, gen=6, seed0=0, temperature=0.0):
    rng = np.random.default_rng(42)
    return [
        Request(prompt=rng.integers(0, vocab, size=(int(rng.integers(3, 9)),)),
                max_new_tokens=gen, seed=seed0 + i, temperature=temperature)
        for i in range(n)
    ]


def clone(req, **kw):
    base = dict(prompt=np.asarray(req.prompt),
                max_new_tokens=req.max_new_tokens,
                temperature=req.temperature, seed=req.seed,
                eos_id=req.eos_id, x_a=req.x_a)
    base.update(kw)
    return Request(**base)


@pytest.fixture(scope="module")
def qwen():
    return ServeEngine("qwen2-0.5b", slots=4, cache_cap=32, seed=0)


# ---------------------------------------------------------------------------
# request queue + slot ring units
# ---------------------------------------------------------------------------
def test_queue_submit_close():
    q = RequestQueue()
    f = q.submit(Request(prompt=[1, 2]))
    assert len(q) == 1 and not f.done()
    r = q.try_get()
    assert r is not None and r.rid == 0 and r.t_submit > 0
    assert q.try_get() is None and q.empty()
    q.close()
    assert q.closed
    with pytest.raises(RuntimeError):
        q.submit(Request(prompt=[3]))


def test_slot_ring_admit_evict_order():
    ring = SlotRing(2)
    a, b = Request(prompt=[1], max_new_tokens=2), \
        Request(prompt=[2, 3], max_new_tokens=1)
    sa, sb = ring.admit(a, 0.0), ring.admit(b, 0.0)
    assert (sa, sb) == (0, 1) and not ring.has_free()
    assert list(ring.feed_tokens()) == [1, 2]
    assert ring.active_mask().all()
    # slot 0: prompt done after 1 feed -> first sampled token recorded
    assert not ring.state(sa).consume(7, 1.0)
    assert ring.state(sa).out == [7]
    # slot 1 still prefilling: sampled output discarded
    assert not ring.state(sb).consume(9, 1.0)
    assert ring.state(sb).out == [] and ring.state(sb).next_feed() == 3
    # eviction recycles the slot in ring order
    assert ring.state(sa).consume(8, 2.0)
    comp = ring.evict(sa, 2.0)
    assert comp.tokens == [7, 8] and ring.admit(
        Request(prompt=[5]), 0.0) == sa


def test_request_validation():
    with pytest.raises(ValueError):
        Request(prompt=[])
    with pytest.raises(ValueError):
        Request(prompt=[1], max_new_tokens=0)


# ---------------------------------------------------------------------------
# golden prefill: the prompt is consumed for real
# ---------------------------------------------------------------------------
def test_one_shot_golden_greedy(qwen):
    """Greedy tokens for a fixed (seed, prompt) are pinned — this is the
    regression test for the old driver that discarded the caller's prompt
    and prefilled on freshly drawn random tokens."""
    out = qwen.serve([Request(prompt=[3, 1, 4, 1, 5, 9, 2, 6],
                              max_new_tokens=8, seed=0)])[0]
    assert out.tokens == [93, 75, 444, 444, 489, 109, 117, 491]
    assert out.prompt_len == 8 and out.finish_reason == "length"


def test_prefill_conditions_on_prompt(qwen):
    a = qwen.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=4)])[0]
    b = qwen.serve([Request(prompt=[9, 9, 9, 9], max_new_tokens=4)])[0]
    c = qwen.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=4)])[0]
    assert a.tokens == c.tokens          # deterministic greedy
    assert a.tokens != b.tokens          # ...and prompt-dependent


# ---------------------------------------------------------------------------
# slot parity: batched-vs-solo is bit-for-bit
# ---------------------------------------------------------------------------
def _parity_engine(arch):
    eng = ServeEngine(arch, slots=4, cache_cap=32, seed=0)
    reqs = make_requests(eng.cfg.vocab_size, 6)
    batched = eng.serve(reqs)            # 6 requests on 4 slots: slots are
    assert len(batched) == 6             # recycled mid-flight (continuous
    assert eng.ring.admitted >= 6        # batching, staggered admission)
    for i, r in enumerate(reqs):
        solo = eng.serve([clone(r)])[0]  # alone: 1 of 4 slots active
        assert solo.tokens == batched[i].tokens, f"req {i} diverged"
    return eng, reqs, batched


def test_slot_parity_kv_cache():
    eng, reqs, batched = _parity_engine("qwen2-0.5b")
    # token-level oracle: plain B=1 decode, no slot axis at all
    ref = reference_decode(eng.cfg, eng.params, clone(reqs[0]),
                           cache_cap=32)
    assert ref == batched[0].tokens
    assert eng.stats["decode_compiles"] == 1


def test_slot_parity_recurrent_rglru():
    # recurrentgemma reduced = (rglru, dense) + (attn, dense): exercises
    # the recurrent h/conv state ring AND a KV ring in one stack
    eng, reqs, batched = _parity_engine("recurrentgemma-9b")
    ref = reference_decode(eng.cfg, eng.params, clone(reqs[0]),
                           cache_cap=32)
    assert ref == batched[0].tokens


def test_slot_parity_recurrent_rwkv():
    # rwkv6 reduced = (rwkv, rwkv_cm): wkv matrix state + token-shift regs
    eng, reqs, batched = _parity_engine("rwkv6-1.6b")
    ref = reference_decode(eng.cfg, eng.params, clone(reqs[0]),
                           cache_cap=32)
    assert ref == batched[0].tokens


def test_slot_parity_across_slot_counts(qwen):
    """The same request stream through a differently sized slot batch
    (4 vs 8 slots) yields identical tokens."""
    reqs = make_requests(qwen.cfg.vocab_size, 5)
    eng8 = ServeEngine("qwen2-0.5b", slots=8, cache_cap=32,
                       params=qwen.params)
    out4 = qwen.serve([clone(r) for r in reqs])
    out8 = eng8.serve([clone(r) for r in reqs])
    assert [c.tokens for c in out4] == [c.tokens for c in out8]


# ---------------------------------------------------------------------------
# sampling: runtime scalars, per-request keys
# ---------------------------------------------------------------------------
def test_temperature_is_runtime_scalar(qwen):
    """Mixed greedy + sampled batch: no recompile, greedy slots match
    their solo greedy decode, sampling is seed-deterministic."""
    compiles0 = qwen.stats["decode_compiles"]
    greedy = Request(prompt=[3, 1, 4, 1, 5, 9, 2, 6], max_new_tokens=8,
                     seed=0)
    sampled = Request(prompt=[2, 7, 1, 8], max_new_tokens=8, seed=11,
                      temperature=0.7)
    mixed = qwen.serve([clone(greedy), clone(sampled), clone(sampled)])
    assert qwen.stats["decode_compiles"] == compiles0 == 1
    assert mixed[0].tokens == [93, 75, 444, 444, 489, 109, 117, 491]
    assert mixed[1].tokens == mixed[2].tokens          # same seed
    # sampled stream matches the plain B=1 oracle (same key schedule)
    ref = reference_decode(qwen.cfg, qwen.params, clone(sampled),
                           cache_cap=32)
    assert ref == mixed[1].tokens
    diff = qwen.serve([clone(sampled, seed=12)])[0]
    assert diff.tokens != mixed[1].tokens              # key actually used


def test_eos_eviction(qwen):
    base = qwen.serve([Request(prompt=[5, 4, 3], max_new_tokens=6)])[0]
    eos = base.tokens[2]
    out = qwen.serve([Request(prompt=[5, 4, 3], max_new_tokens=6,
                              eos_id=eos)])[0]
    assert out.tokens == base.tokens[:3]
    assert out.finish_reason == "eos"


# ---------------------------------------------------------------------------
# open loop + driver satellites
# ---------------------------------------------------------------------------
def test_open_loop_completes_all(qwen):
    reqs = synthetic_requests(8, qwen.cfg.vocab_size, seed=3,
                              max_new_tokens=5)
    done = open_loop(qwen, reqs, qps=500.0, seed=0)
    assert len(done) == 8
    assert all(len(c.tokens) == 5 for c in done)
    assert all(c.t_first >= c.t_submit and c.t_done >= c.t_first
               for c in done)
    stats = qwen.last_run_stats
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["decode_compiles"] == 1


def test_futures_resolve(qwen):
    q = RequestQueue()
    futs = [q.submit(r) for r in make_requests(qwen.cfg.vocab_size, 3,
                                               gen=3)]
    q.close()
    qwen.run(q)
    assert all(f.done() for f in futs)
    assert [len(f.result().tokens) for f in futs] == [3, 3, 3]


def test_launch_serve_argv_passthrough(qwen):
    """`repro.launch.serve.main` takes argv directly — no sys.argv
    mutation (the old examples/serve_split.py hack)."""
    from repro.launch.serve import main as serve_main
    argv_before = list(sys.argv)
    done = serve_main(["--arch", "qwen2-0.5b", "--prompt", "3,1,4,1,5,9,2,6",
                       "--batch", "1", "--slots", "4", "--gen", "8",
                       "--cache-cap", "32"])
    assert sys.argv == argv_before
    assert done[0].tokens == [93, 75, 444, 444, 489, 109, 117, 491]
