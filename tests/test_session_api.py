"""Session API: staged lifecycle, compile-once/run-many sweep reuse,
back-compat of the `run_experiment` wrapper, per-epoch callbacks, and
the planner `PlanTable` satellite."""
import math

import pytest

from repro.api import (EarlyStop, EvalEvery, ExperimentConfig, History,
                       MetricStream, Session, compile_stats, run_sweep)
from repro.api.session import CompiledProgram, Planned, Prepared
from repro.core.runtime import run_experiment

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=2,
            batch_size=64, w_a=4, w_p=4)


def _cfg(**kw):
    d = dict(BASE)
    d.update(kw)
    return ExperimentConfig(**d)


# ---------------------------------------------------------------------------
# staged lifecycle
# ---------------------------------------------------------------------------
def test_stages_return_inspectable_artifacts():
    sess = Session(_cfg())
    prep = sess.prepare()
    assert isinstance(prep, Prepared)
    assert prep.n_samples > 0 and prep.d_a > 0 and prep.d_p > 0
    pl = sess.plan()
    assert isinstance(pl, Planned)
    assert (pl.w_a, pl.w_p, pl.batch_size) == (4, 4, 64)
    sim = sess.simulate()
    assert len(sim.events) > 0
    prog = sess.compile()
    assert isinstance(prog, CompiledProgram)
    assert prog.schedule is not None and prog.sim is sim
    # stages memoize on the session
    assert sess.prepare() is prep
    assert sess.plan() is pl
    assert sess.compile() is prog


def test_planner_stage_resolves_workers():
    sess = Session(_cfg(use_planner=True))
    pl = sess.plan()
    assert pl.plan is not None
    assert pl.w_a >= 2 and pl.w_p >= 2
    assert pl.run_cfg.w_a == pl.w_a and pl.run_cfg.batch_size == \
        pl.batch_size


def test_structural_key_drops_seed_lr_dp_value():
    a = Session(_cfg(seed=0)).structural_key()
    b = Session(_cfg(seed=7)).structural_key()
    assert a == b
    assert Session(_cfg(lr=5e-3)).structural_key() == a
    d1 = Session(_cfg(dp_mu=0.5)).structural_key()
    d2 = Session(_cfg(dp_mu=2.0)).structural_key()
    assert d1 == d2 and d1 != a          # dp on/off IS structural
    assert Session(_cfg(batch_size=32)).structural_key() != a
    assert Session(_cfg(engine="event")).structural_key() != a


# ---------------------------------------------------------------------------
# compile-once / run-many
# ---------------------------------------------------------------------------
def test_sweep_reuses_compiled_program_across_seeds_and_lr():
    """>=4 same-shape points -> exactly one compile (the acceptance
    criterion), warm points flagged as cache hits."""
    cfgs = [_cfg(seed=0), _cfg(seed=1), _cfg(seed=2, lr=3e-3),
            _cfg(seed=3)]
    before = compile_stats()
    sw = run_sweep(cfgs)
    assert sw.stats["n_points"] == 4
    assert sw.stats["compiles"] <= 1     # 0 if an earlier test compiled it
    assert [r.compile_cache_hit for r in sw.results].count(True) >= 3
    after = compile_stats()
    assert after["hits"] - before["hits"] >= 3
    # different seeds still produce different training runs
    finals = [r["final"] for r in sw.results]
    assert len(set(finals)) > 1


def test_sweep_reuse_across_dp_mu():
    """dp_mu varies the runtime sigma, not the compiled structure."""
    sw = run_sweep([_cfg(dp_mu=0.5), _cfg(dp_mu=1.0), _cfg(dp_mu=2.0)])
    assert sw.stats["compiles"] <= 1
    assert sum(r.compile_cache_hit for r in sw.results) >= 2
    finals = [r["final"] for r in sw.results]
    assert len(set(finals)) == 3         # sigma really took effect


def test_exact_reuse_is_seed_faithful():
    """reuse="exact" (the run_experiment scope) never adopts another
    seed's timetable."""
    s0 = Session(_cfg(seed=11), reuse="exact")
    s0.compile()
    s1 = Session(_cfg(seed=12), reuse="exact")
    s1.compile()
    assert not s1.compile_cache_hit
    s2 = Session(_cfg(seed=11), reuse="exact")
    s2.compile()
    assert s2.compile_cache_hit


def test_dp_flip_raises_on_compiled_program():
    sess = Session(_cfg())
    sess.compile()
    with pytest.raises(ValueError, match="dp"):
        sess.run(dp_mu=0.5)


# ---------------------------------------------------------------------------
# back-compat: run_experiment == the pre-redesign monolith
# ---------------------------------------------------------------------------
def _legacy_run_experiment(cfg: ExperimentConfig) -> dict:
    """The pre-Session `run_experiment` body, verbatim (data -> profile
    -> DES -> trainer.replay -> dict), as the golden reference."""
    from repro.api.session import build_profile
    from repro.core.des import RunConfig, simulate
    from repro.core.trainer import VFLTrainer
    from repro.data.synthetic import load
    from repro.data.vertical import psi_align, vertical_split
    from repro.dp.gdp import GDPConfig

    ds = load(cfg.dataset, seed=cfg.seed, scale=cfg.scale)
    tr, te = ds.split(seed=cfg.seed)
    a_tr, p_tr = vertical_split(tr, seed=cfg.seed,
                                n_features_active=cfg.features_active)
    a_te, p_te = vertical_split(te, seed=cfg.seed,
                                n_features_active=cfg.features_active)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    profile = build_profile(cfg, a_tr.X.shape[1], p_tr.X.shape[1])
    w_a, w_p, B = cfg.w_a, cfg.w_p, cfg.batch_size
    run_cfg = RunConfig(
        method=cfg.method, n_samples=a_tr.X.shape[0], batch_size=B,
        n_epochs=cfg.n_epochs, w_a=w_a, w_p=w_p, profile=profile,
        p=cfg.p, q=cfg.q,
        t_ddl=(0.0 if cfg.disable_deadline else cfg.t_ddl),
        dt0=cfg.dt0, jitter=cfg.jitter, seed=cfg.seed)
    sim = simulate(run_cfg)
    gdp = None
    if math.isfinite(cfg.dp_mu):
        gdp = GDPConfig(mu=cfg.dp_mu, clip=1.0, minibatch=B,
                        global_batch=B,
                        n_queries=run_cfg.n_batches * cfg.n_epochs)
    trainer = VFLTrainer(run_cfg, a_tr, p_tr, a_te, p_te, ds.task,
                         seed=cfg.seed, resnet=cfg.resnet, gdp=gdp,
                         depth=cfg.depth,
                         disable_semi_async=cfg.disable_semi_async)
    res = trainer.replay(sim, engine=cfg.engine, pack=cfg.pack)
    return {
        "method": cfg.method, "dataset": cfg.dataset, "task": ds.task,
        "metric": res.metric_name, "final": res.final_metric,
        "history": res.history, "losses": res.losses,
        "sim_s": sim.total_time,
        "sim_s_per_epoch": sim.total_time / max(cfg.n_epochs, 1),
        "cpu_util": sim.cpu_util,
        "waiting_per_epoch": sim.waiting_per_epoch,
        "comm_mb": sim.comm_mb, "staleness": res.staleness_mean,
        "lane_occupancy": res.lane_occupancy,
        "drops": sim.stats["drops"], "w_a": sim.stats["w_a"],
        "w_p": sim.stats["w_p"], "batch_size": B,
        "plan": None,
    }


@pytest.mark.parametrize("method", ["vfl", "pubsub"])
@pytest.mark.parametrize("engine", ["compiled", "event"])
def test_run_experiment_matches_legacy_monolith(method, engine):
    """The wrapper returns a dict with the same keys and same values
    (fixed seed) as the pre-redesign one-shot implementation."""
    cfg = _cfg(method=method, engine=engine, seed=3)
    got = run_experiment(cfg)
    want = _legacy_run_experiment(cfg)
    assert set(got) == set(want)
    assert got == want


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------
def test_eval_every_custom_cadence():
    sess = Session(_cfg(n_epochs=4))
    out = sess.run(eval_every_epoch=False, callbacks=[EvalEvery(2)])
    assert len(out["history"]) == 2      # epochs 2 and 4 only


def test_early_stop_by_target():
    sess = Session(_cfg(n_epochs=4))
    out = sess.run(callbacks=[EarlyStop(target=-1.0,
                                        higher_better=True)])
    assert len(out["history"]) == 1      # stopped after epoch 1


def test_eval_every_composes_with_eval_every_epoch():
    """EvalEvery is a no-op on epochs already in the history, so the
    default eval_every_epoch=True path never double-appends."""
    sess = Session(_cfg(n_epochs=2))
    out = sess.run(callbacks=[EvalEvery(1)])     # eval_every_epoch=True
    assert len(out["history"]) == 2


def test_early_stop_patience_resets_between_sweep_points():
    """A shared EarlyStop instance must not leak patience state from
    one sweep point into the next (it resets at epoch 1)."""
    cb = EarlyStop(patience=1, higher_better=True)
    sw = run_sweep([_cfg(n_epochs=2, seed=21), _cfg(n_epochs=2, seed=22)],
                   callbacks=[cb])
    # each point ran at least its first epoch on its own merits
    for r in sw.results:
        assert len(r["history"]) >= 1


def test_metric_stream_and_history_callbacks():
    records = []
    hist = History()
    sess = Session(_cfg())
    sess.run(callbacks=[MetricStream(records.append), hist])
    assert [r["epoch"] for r in records] == [1, 2]
    assert all("metric" in r for r in records)
    assert [r["metric"] for r in hist.records] == \
        [r["metric"] for r in records]


# ---------------------------------------------------------------------------
# satellites: epochs_to_target sentinel + planner PlanTable
# ---------------------------------------------------------------------------
def test_epochs_to_target_returns_inf_when_unreached():
    from repro.core.trainer import TrainResult
    res = TrainResult(metric_name="auc", history=[0.5, 0.7, 0.9],
                      losses=[1.0, 0.5, 0.2], final_metric=0.9,
                      staleness_mean=0.0, n_updates=3)
    assert res.epochs_to_target(0.7, True) == 2
    assert res.epochs_to_target(0.9, True) == 3      # reached on last
    assert res.epochs_to_target(0.95, True) == math.inf   # never
    assert res.epochs_to_target(0.2, False) == math.inf   # lower-better


def test_plan_table_argmin_matches_plan():
    from repro.core.cost_model import PartyProfile, SystemProfile
    from repro.core.planner import plan

    profile = SystemProfile(active=PartyProfile(cores=16),
                            passive=PartyProfile(cores=24))
    for objective in ("paper", "throughput"):
        p = plan(profile, w_a_range=(2, 10), w_p_range=(2, 10),
                 keep_table=True, objective=objective)
        t = p.table
        assert t is not None
        assert t.costs.shape == (len(t.was), len(t.wps), len(t.batches))
        assert t.argmin() == (p.w_a, p.w_p, p.batch_size)
        i = t.was.index(p.w_a)
        j = t.wps.index(p.w_p)
        r = t.batches.index(p.batch_size)
        assert t.costs[i, j, r] == pytest.approx(p.cost)
    assert plan(profile, w_a_range=(2, 10), w_p_range=(2, 10)).table \
        is None
