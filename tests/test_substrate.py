"""Data pipeline, optimizers, DP accountant, checkpointing."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import load_step, restore, save
from repro.data.synthetic import DATASETS, load
from repro.data.vertical import (batch_ids, psi_align, vertical_split)
from repro.dp.gdp import (GDPConfig, compose_mu, mu_to_epsilon_delta,
                          noise_sigma)
from repro.optim.optimizers import (adam, apply_updates,
                                    clip_by_global_norm,
                                    masked_replica_update,
                                    packed_replica_update, sgd,
                                    stack_states)
from repro.optim.schedules import constant, linear_warmup_cosine


# ---------------------------------------------------------------------------
def test_datasets_match_paper_cardinality():
    specs = {"energy": (19735, 27, "regression"),
             "blog": (60021, 280, "regression"),
             "bank": (40787, 48, "classification"),
             "credit": (30000, 23, "classification")}
    for name, (n, d, task) in specs.items():
        ds = load(name, scale=1.0)
        assert ds.n == n and ds.d == d and ds.task == task


def test_vertical_split_disjoint_and_complete():
    ds = load("credit", scale=0.02)
    a, p = vertical_split(ds, n_features_active=5)
    assert a.X.shape[1] == 5 and p.X.shape[1] == ds.d - 5
    assert p.y is None and a.y is not None


def test_psi_alignment():
    ds = load("bank", scale=0.02)
    a, p = vertical_split(ds)
    # passive party misses some rows
    p2 = type(p)(p.ids[10:], p.X[10:], None)
    a2, p3 = psi_align(a, p2)
    assert len(a2.ids) == len(p3.ids) == ds.n - 10
    assert (a2.ids == p3.ids).all()               # same order, same samples


def test_batch_ids_shared_and_epoch_varying():
    b0 = batch_ids(1000, 128, seed=3, epoch=0)
    b0b = batch_ids(1000, 128, seed=3, epoch=0)
    b1 = batch_ids(1000, 128, seed=3, epoch=1)
    assert (b0 == b0b).all()
    assert not (b0 == b1).all()
    assert b0.shape == (7, 128)


# ---------------------------------------------------------------------------
def test_adam_quadratic_convergence():
    opt = adam(0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        ups, state = opt.update(grads, state, params)
        params = apply_updates(params, ups)
    assert abs(float(params["x"])) < 1e-2


def test_sgd_momentum():
    opt = sgd(0.1, momentum=0.9)
    params = {"x": jnp.asarray(1.0)}
    state = opt.init(params)
    ups, state = opt.update({"x": jnp.asarray(1.0)}, state, params)
    params = apply_updates(params, ups)
    assert float(params["x"]) == pytest.approx(0.9)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(6.0)
    assert np.linalg.norm(np.asarray(clipped["a"])) == pytest.approx(1.0)


@pytest.mark.parametrize("opt_name", ["adam", "sgd", "momentum"])
def test_flat_lane_step_matches_per_leaf(opt_name):
    """The fused flat update path (`flat=True`: per-lane pytrees
    flattened to one contiguous f32 vector, optimizer stepped as
    single-leaf trees) is bit-compatible with the per-leaf path for
    SGD/momentum/Adam, on both the packed (gather/scatter by replica
    index) and masked (dense) updates — including the no-op lanes'
    untouched params and step counters.  This is the CPU-side parity
    pin for a path whose *default* is on only off-CPU."""
    from repro.models import tabular
    opt = {"adam": adam(1e-2), "sgd": sgd(1e-2),
           "momentum": sgd(1e-2, momentum=0.9)}[opt_name]
    reps = [tabular.init_bottom(k, 12, depth=3, width=16, emb_dim=8)
            for k in jax.random.split(jax.random.PRNGKey(0), 4)]
    stack = stack_states(reps)
    st0 = stack_states([opt.init(t) for t in reps])

    g_l = jax.tree.map(lambda x: x[:2] * 0.1 + 1.0, stack)   # 2 lanes
    rep = jnp.array([2, 0])
    mask = jnp.array([True, False])                          # lane 1 idle
    a = packed_replica_update(opt, g_l, st0, stack, rep, mask, flat=False)
    b = packed_replica_update(opt, g_l, st0, stack, rep, mask, flat=True)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)

    g_m = jax.tree.map(lambda x: x * 0.1 + 1.0, stack)
    m = jnp.array([True, False, True, False])
    a = masked_replica_update(opt, g_m, st0, stack, m, flat=False)
    b = masked_replica_update(opt, g_m, st0, stack, m, flat=True)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


def test_schedules():
    s = linear_warmup_cosine(1.0, warmup=10, total=100)
    assert float(s(5)) == pytest.approx(0.5)
    assert float(s(10)) <= 1.0
    assert float(s(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(constant(0.3)(17)) == pytest.approx(0.3)


# ---------------------------------------------------------------------------
def test_gdp_sigma_eq17():
    cfg = GDPConfig(mu=1.0, minibatch=32, global_batch=256, n_queries=100)
    assert noise_sigma(cfg) == pytest.approx(32 * math.sqrt(100) / 256)
    # stronger privacy (smaller mu) -> more noise
    assert noise_sigma(GDPConfig(mu=0.5, minibatch=32, global_batch=256,
                                 n_queries=100)) > noise_sigma(cfg)
    assert noise_sigma(GDPConfig(mu=math.inf)) == 0.0


def test_gdp_composition_and_conversion():
    assert compose_mu([3.0, 4.0]) == pytest.approx(5.0)
    e1 = mu_to_epsilon_delta(0.5)
    e2 = mu_to_epsilon_delta(2.0)
    assert e1 < e2                                 # monotone in mu


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": [{"b": jnp.ones((4,), jnp.int32)}]}
    path = os.path.join(tmp_path, "ck.msgpack")
    save(path, tree, step=42)
    like = jax.tree.map(jnp.zeros_like, tree)
    back = restore(path, like)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["nested"][0]["b"]),
                                  np.asarray(tree["nested"][0]["b"]))
    assert load_step(path) == 42


def test_checkpoint_structure_mismatch(tmp_path):
    path = os.path.join(tmp_path, "ck.msgpack")
    save(path, {"a": jnp.zeros((2,))})
    with pytest.raises(AssertionError):
        restore(path, {"b": jnp.zeros((2,))})
