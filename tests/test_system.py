"""End-to-end behaviour tests of the paper's system: DES + real training,
speedup/utilization ordering, accuracy parity, ablation directions.

The whole module is marked `slow` (multi-epoch full-system runs) and is
deselected by the default tier-1 loop; run with `--runslow`.  Fast
engine-level coverage of the same training semantics lives in
tests/test_engine_parity.py and tests/test_trainer.py."""
import math

import numpy as np
import pytest

from repro.core.runtime import ExperimentConfig, run_experiment

pytestmark = pytest.mark.slow

FAST = dict(scale=0.05, n_epochs=3, batch_size=64)


@pytest.fixture(scope="module")
def all_methods():
    out = {}
    for m in ("vfl", "vfl_ps", "avfl", "avfl_ps", "pubsub"):
        out[m] = run_experiment(ExperimentConfig(method=m, dataset="bank",
                                                 **FAST))
    return out


def test_accuracy_parity(all_methods):
    """PubSub-VFL matches baseline accuracy (paper Table 1)."""
    aucs = {m: r["final"] for m, r in all_methods.items()}
    assert aucs["pubsub"] >= max(aucs.values()) - 0.02
    assert all(a > 0.8 for a in aucs.values()), aucs


def test_speedup_and_utilization(all_methods):
    """2x+ faster than pure VFL; top-tier utilization (paper Fig. 3)."""
    t = {m: r["sim_s"] for m, r in all_methods.items()}
    assert t["vfl"] / t["pubsub"] > 1.8
    u = {m: r["cpu_util"] for m, r in all_methods.items()}
    assert u["pubsub"] >= max(u.values()) - 0.05
    assert u["pubsub"] > 0.7


def test_pubsub_lowest_active_waiting(all_methods):
    """Decoupling eliminates worker-side waiting (paper Tables 2/9)."""
    w = {m: r["waiting_per_epoch"] for m, r in all_methods.items()}
    assert w["pubsub"] <= w["vfl_ps"]


def test_heterogeneity_resilience():
    """Under a 50:14 core split PubSub keeps the utilization lead
    (paper Fig. 4: 87.42% vs 42.12%)."""
    r_ps = run_experiment(ExperimentConfig(method="avfl_ps", dataset="bank",
                                           cores_a=50, cores_p=14, **FAST))
    r_pub = run_experiment(ExperimentConfig(method="pubsub", dataset="bank",
                                            cores_a=50, cores_p=14, **FAST))
    assert r_pub["cpu_util"] > r_ps["cpu_util"]
    assert r_pub["sim_s"] < r_ps["sim_s"]


def test_dp_noise_costs_accuracy():
    """Smaller mu (stronger privacy) hurts accuracy (paper Fig. 5)."""
    base = run_experiment(ExperimentConfig(method="pubsub", dataset="bank",
                                           **FAST))
    noisy = run_experiment(ExperimentConfig(method="pubsub", dataset="bank",
                                            dp_mu=0.1, **FAST))
    assert noisy["final"] <= base["final"] + 1e-6
    assert base["final"] - noisy["final"] < 0.5    # still learns


def test_regression_task_runs():
    r = run_experiment(ExperimentConfig(method="pubsub", dataset="energy",
                                        **FAST))
    assert r["metric"] == "rmse"
    assert r["final"] < 1.05                       # better than predicting 0


def test_planner_feasible_config():
    r = run_experiment(ExperimentConfig(method="pubsub", dataset="credit",
                                        use_planner=True, **FAST))
    assert r["plan"] is not None
    assert r["w_a"] >= 2 and r["w_p"] >= 2
    assert math.isfinite(r["sim_s"])


def test_staleness_bounded_by_buffers():
    r = run_experiment(ExperimentConfig(method="pubsub", dataset="bank",
                                        p=2, q=2, **FAST))
    r_big = run_experiment(ExperimentConfig(method="pubsub", dataset="bank",
                                            p=8, q=8, **FAST))
    assert r["staleness"] <= r_big["staleness"] + 1.0
