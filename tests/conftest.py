import os
import sys

import pytest

# tests run on the single real CPU device; only the dry-run forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# persistent XLA compilation cache: repeat pytest runs skip the ~8s
# compiled-engine jit (REPRO_XLA_CACHE=0 disables; see core/xla_cache.py)
from repro.core.xla_cache import enable_persistent_cache  # noqa: E402

enable_persistent_cache()


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked slow (long multi-epoch system runs)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long multi-epoch system test, deselected by default "
        "(enable with --runslow or -m slow)")


def pytest_collection_modifyitems(config, items):
    """Tier-1 (`pytest -x -q`) deselects `slow` tests so the default loop
    stays CI-friendly; `--runslow` (or an explicit `-m slow`) re-enables
    them."""
    if config.getoption("--runslow"):
        return
    if config.getoption("-m"):
        return          # explicit marker expressions take precedence
    skip_slow = pytest.mark.skip(reason="slow: use --runslow to include")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
