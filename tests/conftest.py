import os
import sys

# tests run on the single real CPU device; only the dry-run forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
