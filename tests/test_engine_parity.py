"""Compiled-engine parity: the jitted scan replay must reproduce the
legacy event-loop replay (same seed, same event log) for every method,
and its device-resident DP publish must match the fused cut-layer
reference semantics."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import METHODS, RunConfig, simulate
from repro.core.schedule import compile_schedule
from repro.core.trainer import VFLTrainer
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split
from repro.kernels.cut_layer.ref import cut_layer_ref
from repro.models import tabular


def _setup(method, n_epochs=2, **kw):
    ds = load("credit", scale=0.05)
    tr, te = ds.split()
    a_tr, p_tr = vertical_split(tr)
    a_te, p_te = vertical_split(te)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=64, n_epochs=n_epochs, w_a=4, w_p=4,
                    profile=prof)
    sim = simulate(cfg)
    mk = lambda: VFLTrainer(cfg, a_tr, p_tr, a_te, p_te, ds.task,
                            depth=4, **kw)
    return cfg, sim, mk


@pytest.mark.parametrize("method", METHODS)
def test_compiled_matches_event_engine(method):
    """Same seed, same log => identical convergence semantics (segmented
    lane layout, the default)."""
    cfg, sim, mk = _setup(method)
    res_e = mk().replay(sim, engine="event")
    res_c = mk().replay(sim, engine="compiled")
    np.testing.assert_allclose(res_c.losses, res_e.losses,
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(res_c.history, res_e.history,
                               rtol=1e-3, atol=1e-4)
    assert abs(res_c.final_metric - res_e.final_metric) < 5e-3
    assert res_c.staleness_mean == res_e.staleness_mean
    assert res_c.n_updates == res_e.n_updates


@pytest.mark.parametrize("method", METHODS)
def test_segmented_matches_packed_layout(method):
    """The segmented run chain is a pure re-grouping of the packed tick
    stream executed by cond-free bodies: same per-op math on the same
    inputs, so losses and metrics agree to float tolerance."""
    cfg, sim, mk = _setup(method)
    res_p = mk().replay(sim, engine="compiled", pack="packed")
    res_s = mk().replay(sim, engine="compiled", pack="segmented")
    np.testing.assert_allclose(res_s.losses, res_p.losses,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(res_s.history, res_p.history,
                               rtol=1e-5, atol=1e-6)
    assert res_s.staleness_mean == res_p.staleness_mean
    assert res_s.n_updates == res_p.n_updates


@pytest.mark.parametrize("method", METHODS)
def test_packed_matches_dense_layout(method):
    """The packed work-row layout is a pure re-timing of the dense
    layout: same per-op math on the same inputs, so losses and metrics
    agree to float tolerance (only reduction order of the on-device
    loss accumulator differs)."""
    cfg, sim, mk = _setup(method)
    res_d = mk().replay(sim, engine="compiled", pack="dense")
    res_p = mk().replay(sim, engine="compiled", pack="packed")
    np.testing.assert_allclose(res_p.losses, res_d.losses,
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(res_p.history, res_d.history,
                               rtol=1e-5, atol=1e-6)
    assert res_p.staleness_mean == res_d.staleness_mean
    assert res_p.n_updates == res_d.n_updates
    # NOTE: no occupancy ordering assert here — on tiny bursty configs
    # the dense layout can be the denser one (the packed engine's merged
    # passive cond charges both passive widths whenever either phase
    # runs); the ≥90% regression on the benchmark-scale pubsub config
    # lives in test_schedule_pack.py.


def test_schedule_preserves_event_order_invariants():
    """Compile-time invariants of the dense tick program: every consumed
    slot was produced earlier (or same tick across the phase boundary),
    lane occupancy is one op per replica per tick, rings are bounded.
    (The packed layout's invariants live in test_schedule_pack.py.)"""
    cfg, sim, _ = _setup("pubsub", n_epochs=3)
    sched = compile_schedule(cfg, sim.events, n_rep_a=4, n_rep_p=4,
                             n_samples=cfg.n_samples, pack="dense")
    assert len(sched.segments) == cfg.n_epochs
    assert sched.n_updates > 0
    produced = {}     # emb slot -> produce tick (live span check)
    tick0 = 0
    for seg in sched.segments:
        T = seg.pf_bid.shape[0]
        for t in range(T):
            g = tick0 + t
            for r in np.nonzero(seg.pf_bid[t] >= 0)[0]:
                produced[int(seg.pf_slot[t, r])] = g
            for r in np.nonzero(seg.as_bid[t] >= 0)[0]:
                slot = int(seg.as_eslot[t, r])
                assert slot in produced and produced[slot] <= g
            # at most one passive op per replica per tick
            assert not np.any((seg.pf_bid[t] >= 0) & (seg.pb_bid[t] >= 0))
        tick0 += T
    assert max(produced, default=0) < sched.emb_slots


def test_publish_embedding_matches_cut_layer_ref():
    """The engine's fused DP publish == hidden forward + cut_layer_ref."""
    key = jax.random.PRNGKey(3)
    kx, kp, kn = jax.random.split(key, 3)
    theta = tabular.init_bottom(kp, 12, depth=4, width=32, emb_dim=16)
    x = jax.random.normal(kx, (40, 12))
    noise = jax.random.normal(kn, (40, 16))
    got = tabular.publish_embedding(theta, x, noise, clip=0.8, sigma=0.3)
    h = tabular.hidden_forward(theta, x)
    last = theta["layers"][-1]
    want = cut_layer_ref(h, last["w"], last["b"], noise, clip=0.8,
                         sigma=0.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_publish_embedding_resnet_matches_residual_cut_layer():
    """The residual ("large model") publish routes through the fused
    cut-layer op with the hidden activation as the kernel's residual
    input, and equals the unfused full forward + clip + noise."""
    key = jax.random.PRNGKey(5)
    kx, kp, kn = jax.random.split(key, 3)
    theta = tabular.init_bottom(kp, 12, depth=4, width=16, emb_dim=16)
    x = jax.random.normal(kx, (40, 12))
    noise = jax.random.normal(kn, (40, 16))
    got = tabular.publish_embedding(theta, x, noise, clip=0.8, sigma=0.3,
                                    resnet=True)
    h = tabular.hidden_forward(theta, x, resnet=True)
    last = theta["layers"][-1]
    want = cut_layer_ref(h, last["w"], last["b"], noise, clip=0.8,
                         sigma=0.3, residual=h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    z = tabular.bottom_forward(theta, x, resnet=True)
    nrm = np.linalg.norm(np.asarray(z), axis=-1, keepdims=True)
    unfused = np.asarray(z) * np.minimum(1.0, 0.8 / np.maximum(nrm, 1e-12)) \
        + 0.3 * np.asarray(noise)
    np.testing.assert_allclose(np.asarray(got), unfused, rtol=1e-5,
                               atol=1e-5)


def test_publish_embedding_dp_semantics():
    """Clip bound respected pre-noise; noise scale matches sigma."""
    key = jax.random.PRNGKey(4)
    kx, kp, kn = jax.random.split(key, 3)
    theta = tabular.init_bottom(kp, 10, depth=3, width=64, emb_dim=64)
    x = 3.0 * jax.random.normal(kx, (256, 10))
    clipped = tabular.publish_embedding(theta, x, None, clip=0.5,
                                        sigma=0.0)
    norms = np.linalg.norm(np.asarray(clipped), axis=-1)
    assert np.all(norms <= 0.5 + 1e-5)

    noise = jax.random.normal(kn, (256, 64))
    noised = tabular.publish_embedding(theta, x, noise, clip=0.5,
                                       sigma=0.25)
    resid = np.asarray(noised) - np.asarray(clipped)
    assert abs(resid.std() - 0.25) < 0.02

    # no-DP fast path: untouched forward
    plain = tabular.publish_embedding(theta, x, None, clip=math.inf,
                                      sigma=0.0)
    np.testing.assert_allclose(
        np.asarray(plain),
        np.asarray(tabular.passive_forward(theta, x)), rtol=1e-6)


@pytest.mark.parametrize("pack", ["packed", "segmented"])
def test_compiled_engine_dp_runs_and_degrades(pack):
    """Device-resident DP in the compiled engine: sigma>0 runs end-to-end
    and heavy noise does not beat the clean run.  (Noise streams differ
    between engines and between layouts — segmented advances the PRNG
    key only on publish ticks — so DP parity is semantic, not bitwise;
    the clip/projection math is pinned bitwise by
    test_publish_embedding_matches_cut_layer_ref.)"""
    from repro.dp.gdp import GDPConfig
    gdp = GDPConfig(mu=0.05, clip=0.5, minibatch=64, global_batch=64,
                    n_queries=200)
    cfg, sim, _ = _setup("pubsub")
    _, _, mk_noisy = _setup("pubsub", gdp=gdp)
    _, _, mk_clean = _setup("pubsub")
    noisy = mk_noisy().replay(sim, engine="compiled", pack=pack)
    clean = mk_clean().replay(sim, engine="compiled", pack=pack)
    assert noisy.final_metric <= clean.final_metric + 0.02


def test_segmented_flat_opt_matches_tree_opt():
    """End-to-end: the segmented engine with the fused flat optimizer
    update (`flat_opt=True`, the off-CPU default) produces the same
    losses as the per-leaf tree update — the carry layout is identical,
    only the update's internal layout differs."""
    from repro.core.jit_pipeline import CompiledReplayEngine

    cfg, sim, mk = _setup("pubsub")
    results = []
    for flat in (False, True):
        t = mk()
        sched = compile_schedule(cfg, sim.events, n_rep_a=t.n_rep_a,
                                 n_rep_p=t.n_rep_p, n_samples=len(t.y),
                                 pack="segmented")
        eng = CompiledReplayEngine(sched, task="classification",
                                   lr=t.lr, seed=cfg.seed, flat_opt=flat)
        data = eng.stage_data(t.Xa, t.Xp, t.y)
        d_emb = t.theta_p[0]["layers"][-1]["b"].shape[0]
        state = eng.init_state(t.theta_a, t.opt_a, t.theta_p, t.opt_p,
                               d_emb)
        for e in range(cfg.n_epochs):
            state = eng.run_segment(state, e, data)
        results.append(eng.finish(state)[-1])
    np.testing.assert_allclose(results[1], results[0], rtol=1e-5,
                               atol=1e-6)


def test_segmented_dp_is_deterministic():
    """Same seed, same log => bit-identical DP losses on the segmented
    engine (the scan-carry PRNG key advances deterministically per
    publish tick)."""
    from repro.dp.gdp import GDPConfig
    gdp = GDPConfig(mu=0.05, clip=0.5, minibatch=64, global_batch=64,
                    n_queries=200)
    cfg, sim, mk = _setup("pubsub", gdp=gdp)
    a = mk().replay(sim, engine="compiled", pack="segmented")
    b = mk().replay(sim, engine="compiled", pack="segmented")
    assert a.losses == b.losses
    assert a.final_metric == b.final_metric
