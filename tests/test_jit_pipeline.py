"""The jit-native bounded-staleness pipeline trains and converges."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jit_pipeline import pipelined_train
from repro.data.synthetic import load
from repro.data.vertical import vertical_split
from repro.models import tabular


def _streams(n_steps=60, B=64, seed=0):
    ds = load("credit", scale=0.05, seed=seed)
    a, p = vertical_split(ds, seed=seed)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, a.X.shape[0], size=(n_steps, B))
    return (jnp.asarray(a.X[idx]), jnp.asarray(p.X[idx]),
            jnp.asarray(a.y[idx].astype(np.float32)), a, p, ds.task)


def test_pipeline_trains_inside_jit():
    xa, xp, y, a, p, task = _streams()
    key = jax.random.PRNGKey(0)
    ka, kp, kt = jax.random.split(key, 3)
    theta_a = {"bottom": tabular.init_bottom(ka, xa.shape[-1], depth=4),
               "top": tabular.init_top(kt)}
    theta_p = tabular.init_bottom(kp, xp.shape[-1], depth=4)
    run = jax.jit(lambda ta, tp: pipelined_train(
        ta, tp, xa, xp, y, lag=3, task=task))
    ta2, tp2, losses = run(theta_a, theta_p)
    losses = np.asarray(losses)
    assert np.isnan(losses[:2]).all()            # warmup
    valid = losses[3:]
    assert np.isfinite(valid).all()
    # training signal: loss decreases substantially over the run
    assert valid[-10:].mean() < valid[:10].mean() * 0.9


def test_pipeline_staleness_matches_sync_when_lag1():
    """lag=1 consumes the just-published embedding = synchronous VFL."""
    xa, xp, y, a, p, task = _streams(n_steps=20)
    key = jax.random.PRNGKey(1)
    ka, kp, kt = jax.random.split(key, 3)
    theta_a = {"bottom": tabular.init_bottom(ka, xa.shape[-1], depth=3),
               "top": tabular.init_top(kt)}
    theta_p = tabular.init_bottom(kp, xp.shape[-1], depth=3)
    _, _, l1 = pipelined_train(theta_a, theta_p, xa, xp, y, lag=1,
                               task=task)
    # manual sync reference for the first step
    z = tabular.passive_forward(theta_p, xp[0])
    loss0, _, _ = tabular.active_step(theta_a, xa[0], z, y[0], task=task)
    np.testing.assert_allclose(float(l1[0]), float(loss0), rtol=1e-5)
