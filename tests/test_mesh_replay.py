"""Mesh-sharded replay, proven bit-for-bit on forced multi-device hosts.

The heavy scenarios spawn a fresh Python process with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
exported before jax imports, so the running pytest process cannot flip
it).  Inside the worker the single-device reference and the mesh run
execute back to back and every exported state leaf is compared at the
byte level; the worker prints a single ``RESULT:`` JSON line that the
test asserts on.  This file is its own worker entry point::

    python tests/test_mesh_replay.py <mode> '<json payload>'

Scenario matrix (ISSUE 7): {pubsub, vfl_ps} x {segmented, packed} x
{DP on, off} x {uneven 6-on-4, padded 3-on-4, divisible 4-on-4}, plus
checkpoint save-on-4/resume-on-1 (and the reverse) and a point-stacked
sweep group laid over the point axis.  The slower combinations carry
``@pytest.mark.slow`` (the multi-device CI leg runs them with
``--runslow``); one default scenario per method keeps tier-1 honest.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=2,
            batch_size=64, w_a=6, w_p=6)


# ---------------------------------------------------------------------------
# worker plumbing
# ---------------------------------------------------------------------------
def _spawn(mode: str, payload: dict, *, device_count: int = 4,
           timeout: int = 3000) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{device_count}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), mode,
         json.dumps(payload)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"worker {mode} failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    lines = [l for l in proc.stdout.splitlines()
             if l.startswith("RESULT:")]
    assert lines, f"worker {mode} printed no RESULT line:\n{proc.stdout}"
    return json.loads(lines[-1][len("RESULT:"):])


def _leaf_hashes(export) -> list:
    """sha256 of every leaf's bytes, in deterministic tree order —
    immediate host copies (lazily-read device comparisons can alias)."""
    import hashlib

    import jax
    out = []
    for leaf in jax.tree.leaves(tuple(export)):
        a = np.asarray(leaf)
        out.append(hashlib.sha256(
            str(a.shape).encode() + str(a.dtype).encode() + a.tobytes()
        ).hexdigest())
    return out


def _worker_run(overrides: dict, n_devices: int, *, callbacks=(),
                state=None):
    from repro.api import ExperimentConfig, Session

    class _Capture:
        state = engine = None

        def __call__(self, ctx):
            self.state, self.engine = ctx.state, ctx.engine

    cap = _Capture()
    cfg = ExperimentConfig(**{**BASE, **overrides})
    sess = Session(cfg, n_devices=n_devices)
    res = sess.run(callbacks=[cap, *callbacks], state=state)
    export = cap.engine.export_state(cap.state)
    return sess, res, export


def _worker_parity(payload: dict) -> dict:
    r1 = _worker_run(payload["overrides"], 1)
    r4 = _worker_run(payload["overrides"], payload.get("n_devices", 4))
    (_, res1, e1), (_, res4, e4) = r1, r4
    return {
        "losses_eq": list(res1.train.losses) == list(res4.train.losses),
        "history_eq": list(res1.train.history) ==
        list(res4.train.history),
        "final_eq": res1.train.final_metric == res4.train.final_metric,
        "bad_leaves": [i for i, (a, b) in enumerate(
            zip(_leaf_hashes(e1), _leaf_hashes(e4))) if a != b],
    }


def _worker_run_save(payload: dict) -> dict:
    """Full reference run + an interrupted run that checkpoints at epoch
    `stop_after` (the checkpoint file is what the resume worker, on a
    DIFFERENT device count, picks up)."""
    from repro.api.callbacks import CheckpointEvery

    class _StopAfter:
        def __init__(self, k):
            self.k = k

        def __call__(self, ctx):
            if ctx.epoch == self.k:
                ctx.stop = True

    n = payload["n_devices"]
    _, full, export = _worker_run(payload["overrides"], n)
    k = payload["stop_after"]
    _worker_run(payload["overrides"], n,
                callbacks=[CheckpointEvery(payload["ckpt"], every=k),
                           _StopAfter(k)])
    return {"losses": list(full.train.losses),
            "history": list(full.train.history),
            "final": full.train.final_metric,
            "hashes": _leaf_hashes(export)}


def _worker_resume(payload: dict) -> dict:
    from repro.api import ExperimentConfig, Session
    from repro.checkpoint.store import restore_state

    cfg = ExperimentConfig(**{**BASE, **payload["overrides"]})
    sess = Session(cfg, n_devices=payload["n_devices"])
    engine = sess.compile().engine
    state = engine.load_state(restore_state(payload["ckpt"]))

    class _Capture:
        state = engine = None

        def __call__(self, ctx):
            self.state, self.engine = ctx.state, ctx.engine

    cap = _Capture()
    res = sess.run(state=state, callbacks=[cap])
    export = cap.engine.export_state(cap.state)
    return {"epoch_restored": int(state.epoch),
            "losses": list(res.train.losses),
            "final": res.train.final_metric,
            "hashes": _leaf_hashes(export)}


def _worker_sweep(payload: dict) -> dict:
    from repro.api import ExperimentConfig
    from repro.api.sweep import run_sweep

    n = payload["n_devices"]
    cfgs = [ExperimentConfig(**{**BASE, **payload["overrides"],
                                "lr": lr, "n_devices": n})
            for lr in payload["lrs"]]
    sw = run_sweep(cfgs, stacked=True)
    return {"stacked_groups": sw.stats["stacked_groups"],
            "points": [{"losses": list(r.train.losses),
                        "final": r.train.final_metric}
                       for r in sw.results]}


_MODES = {"parity": _worker_parity, "run_save": _worker_run_save,
          "resume": _worker_resume, "sweep": _worker_sweep}

if __name__ == "__main__":
    sys.path.insert(0, SRC)
    _payload = json.loads(sys.argv[2]) if len(sys.argv) > 2 else {}
    print("RESULT:" + json.dumps(_MODES[sys.argv[1]](_payload)))
    sys.exit(0)


# ---------------------------------------------------------------------------
# bit-for-bit parity, single device vs 4 forced host devices
# ---------------------------------------------------------------------------
def _assert_parity(overrides: dict):
    got = _spawn("parity", {"overrides": overrides})
    assert got == {"losses_eq": True, "history_eq": True,
                   "final_eq": True, "bad_leaves": []}, got


def test_parity_pubsub_segmented_dp_uneven():
    """6 replicas on 4 devices (padded lanes), DP noise on."""
    _assert_parity({"dp_mu": 1.0})


def test_parity_vfl_ps_segmented_uneven():
    """vfl_ps round barriers (hoisted agg ticks) on padded lanes."""
    _assert_parity({"method": "vfl_ps"})


@pytest.mark.slow
def test_parity_pubsub_packed_dp():
    _assert_parity({"dp_mu": 1.0, "pack": "packed"})


@pytest.mark.slow
def test_parity_pubsub_segmented_padded_3_on_4():
    """3 replicas on 4 devices: one whole device is padding lanes."""
    _assert_parity({"w_a": 3, "w_p": 3})


@pytest.mark.slow
def test_parity_vfl_ps_segmented_dp():
    _assert_parity({"method": "vfl_ps", "dp_mu": 1.0})


@pytest.mark.slow
def test_parity_vfl_ps_segmented_divisible():
    """4 replicas on 4 devices: the divisible case still pads one lane
    per device — a fully-populated lane axis lets the partitioner shard
    the all-lane phase compute, which breaks FMA-contraction parity
    (see slab_plan)."""
    _assert_parity({"method": "vfl_ps", "w_a": 4, "w_p": 4})


@pytest.mark.slow
def test_parity_vfl_ps_packed():
    _assert_parity({"method": "vfl_ps", "pack": "packed",
                    "w_a": 3, "w_p": 3, "n_epochs": 1})


# ---------------------------------------------------------------------------
# checkpoint round-trip across device counts
# ---------------------------------------------------------------------------
def _ckpt_roundtrip(tmp_path, overrides: dict, save_on: int,
                    resume_on: int):
    ckpt = str(tmp_path / "state.msgpack")
    ref = _spawn("run_save", {"overrides": overrides, "n_devices": save_on,
                              "ckpt": ckpt, "stop_after": 1},
                 device_count=max(save_on, 1))
    got = _spawn("resume", {"overrides": overrides,
                            "n_devices": resume_on, "ckpt": ckpt},
                 device_count=max(resume_on, 1))
    assert got["epoch_restored"] == 1
    assert got["losses"] == ref["losses"]
    assert got["final"] == ref["final"]
    assert got["hashes"] == ref["hashes"]


def test_checkpoint_save_on_4_resume_on_1(tmp_path):
    """A mesh-written checkpoint (canonical replica order on disk)
    resumes on a single device, bit-identical to the uninterrupted
    mesh run — whose bytes equal the single-device run by parity."""
    _ckpt_roundtrip(tmp_path, {"dp_mu": 1.0}, save_on=4, resume_on=1)


@pytest.mark.slow
def test_checkpoint_save_on_1_resume_on_4(tmp_path):
    _ckpt_roundtrip(tmp_path, {"dp_mu": 1.0}, save_on=1, resume_on=4)


# ---------------------------------------------------------------------------
# point-stacked sweep groups over the device mesh
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_stacked_sweep_mesh_matches_single_device():
    """run_sweep(stacked=True) with n_devices=4 lays the point axis over
    the mesh; per-point results must equal the n_devices=1 stack."""
    payload = {"overrides": {"w_a": 2, "w_p": 2, "n_epochs": 2},
               "lrs": [0.05, 0.03, 0.02, 0.01]}
    r1 = _spawn("sweep", {**payload, "n_devices": 1}, device_count=1)
    r4 = _spawn("sweep", {**payload, "n_devices": 4}, device_count=4)
    assert r1["stacked_groups"] == r4["stacked_groups"] == 1
    assert r1["points"] == r4["points"]


# ---------------------------------------------------------------------------
# cheap in-process checks (no forced devices needed)
# ---------------------------------------------------------------------------
def test_slab_plan_uneven_6_on_4():
    from repro.core.schedule import slab_plan

    p = slab_plan(6, 4)
    assert p.n_lanes == 8 and p.lanes_per_device == 2
    assert p.lane_of == (0, 1, 2, 3, 4, 6)
    assert p.rep_of == (0, 1, 2, 3, 4, -1, 5, -1)
    assert p.device_load == (2, 2, 1, 1)
    assert not p.is_identity
    # lane_of / rep_of invert each other over the real replicas
    assert all(p.rep_of[p.lane_of[r]] == r for r in range(6))


def test_slab_plan_divisible_keeps_padding():
    """Divisible counts still get one padding lane per device (numerical
    requirement — see the slab_plan docstring), so multi-device plans
    are never the identity; a single device is exempt."""
    from repro.core.schedule import slab_plan

    p = slab_plan(4, 4)
    assert not p.is_identity
    assert p.lanes_per_device == 2 and p.n_lanes == 8
    assert p.device_load == (1, 1, 1, 1)
    assert p.lane_of == (0, 2, 4, 6)
    assert slab_plan(4, 1).is_identity


def test_device_lower_rejects_dense():
    from repro.api import ExperimentConfig, Session
    from repro.core.schedule import device_lower

    sched = Session(ExperimentConfig(**BASE, pack="dense")) \
        .compile().engine.schedule
    with pytest.raises(ValueError, match="pack"):
        device_lower(sched, 4)


def test_make_replay_mesh_requires_visible_devices():
    from repro.core.mesh_replay import make_replay_mesh

    import jax
    n = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_replay_mesh(n)


def test_n_devices_requires_compiled_engine():
    from repro.api import ExperimentConfig, Session

    with pytest.raises(ValueError, match="compiled"):
        Session(ExperimentConfig(**BASE, engine="event"), n_devices=4)


def test_structural_key_includes_device_count():
    from repro.api import ExperimentConfig, Session

    cfg = ExperimentConfig(**BASE)
    k1 = Session(cfg, n_devices=1).structural_key()
    k4 = Session(cfg, n_devices=4).structural_key()
    assert k1 != k4
    assert ("devices", 4) in k4 and ("devices", 1) in k1


def test_single_device_fallthrough_has_no_mesh():
    from repro.api import ExperimentConfig, Session

    eng = Session(ExperimentConfig(**BASE), n_devices=1) \
        .compile().engine
    assert eng.mesh is None
