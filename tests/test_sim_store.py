"""DES `Store`/`Engine` edge cases: the channel-buffer semantics every
replay is lowered from.  Eviction accounting under capacity-1 churn,
`get_timeout` racing a same-tick `put`, cancelled timeout tokens never
double-resuming, and the `drop_filter` hook fault injection installs."""
from repro.core.sim import Engine, Store


def _run(*procs):
    eng = Engine()
    stores = {}

    def store(name, capacity=None):
        if name not in stores:
            stores[name] = Store(eng, capacity)
        return stores[name]

    for p in procs:
        eng.process(p(eng, store))
    eng.run()
    return eng, stores


# ---------------------------------------------------------------------------
# eviction counter under capacity-1 churn
# ---------------------------------------------------------------------------
def test_capacity_one_churn_counts_every_eviction():
    eng = Engine()
    st = Store(eng, capacity=1)
    for i in range(10):
        st.put(i)
    # 10 puts into a 1-slot buffer with no reader: 9 evictions, newest
    # survives
    assert st.n_evicted == 9
    assert list(st.buf) == [9]
    ok, item = st.try_get()
    assert ok and item == 9 and len(st) == 0


def test_put_to_waiter_never_evicts():
    """Delivery to a blocked getter bypasses the buffer entirely — a
    full buffer must not charge an eviction for it."""
    got = []

    def reader(eng, store):
        got.append((yield ("get", store("ch", 1))))

    def writer(eng, store):
        yield ("sleep", 1.0)
        store("ch", 1).put("x")

    _, stores = _run(reader, writer)
    assert got == ["x"]
    assert stores["ch"].n_evicted == 0 and len(stores["ch"]) == 0


# ---------------------------------------------------------------------------
# get_timeout racing a same-tick put
# ---------------------------------------------------------------------------
def test_get_timeout_vs_same_tick_put_delivery_wins():
    """A put scheduled at exactly the deadline tick but sequenced BEFORE
    the timeout fire delivers the item; the timeout token is cancelled
    and the late fire is a no-op."""
    got = []

    def reader(eng, store):
        got.append((yield ("get_timeout", store("ch"), 1.0)))

    def writer(eng, store):
        yield ("sleep", 1.0)              # same t as the deadline...
        store("ch").put("just-in-time")   # ...but pushed first (FIFO seq)

    # writer is processed first, so its t=1.0 resume outranks the
    # timeout_fire pushed by the reader's later get_timeout — the put
    # lands inside the deadline tick
    eng, stores = _run(writer, reader)
    assert got == ["just-in-time"]
    assert not stores["ch"].waiters


def test_get_timeout_fires_then_late_put_buffers():
    """When the deadline fires first, the waiter resumes with None; a
    later put must buffer (the stale token is skipped, not delivered)."""
    got = []

    def reader(eng, store):
        got.append((yield ("get_timeout", store("ch"), 1.0)))
        yield ("sleep", 5.0)              # stay alive past the late put

    def writer(eng, store):
        yield ("sleep", 2.0)
        store("ch").put("too-late")

    _, stores = _run(reader, writer)
    assert got == [None]
    assert list(stores["ch"].buf) == ["too-late"]


# ---------------------------------------------------------------------------
# cancelled tokens never double-resume
# ---------------------------------------------------------------------------
def test_cancelled_waiter_token_never_double_resumes():
    """Deliver at t<deadline, then let the (cancelled) timeout tick
    pass: the reader must be resumed exactly once, and the next get on
    the store must see only items put AFTER the delivery."""
    resumes = []

    def reader(eng, store):
        item = yield ("get_timeout", store("ch"), 2.0)
        resumes.append((eng.now, item))
        # if the cancelled token double-resumed, this second yield would
        # receive the spurious None at t=2
        item2 = yield ("get", store("ch"))
        resumes.append((eng.now, item2))

    def writer(eng, store):
        yield ("sleep", 1.0)
        store("ch").put("first")
        yield ("sleep", 3.0)              # past the dead deadline tick
        store("ch").put("second")

    _run(reader, writer)
    assert resumes == [(1.0, "first"), (4.0, "second")]


def test_fired_token_is_skipped_in_waiter_queue():
    """Two waiters, the first times out: a put must skip the fired
    token and deliver to the live second waiter."""
    got = []

    def fast_reader(eng, store):
        got.append(("fast", (yield ("get_timeout", store("ch"), 1.0))))

    def slow_reader(eng, store):
        got.append(("slow", (yield ("get_timeout", store("ch"), 10.0))))

    def writer(eng, store):
        yield ("sleep", 2.0)
        store("ch").put("x")

    _, stores = _run(fast_reader, slow_reader, writer)
    assert ("fast", None) in got and ("slow", "x") in got
    assert not stores["ch"].waiters


# ---------------------------------------------------------------------------
# drop_filter (fault injection's loss-in-transit hook)
# ---------------------------------------------------------------------------
def test_drop_filter_counts_and_never_reaches_waiters():
    eng = Engine()
    st = Store(eng, capacity=2)
    st.drop_filter = lambda item: item % 2 == 0
    for i in range(6):
        st.put(i)
    assert st.n_dropped == 3              # 0, 2, 4 lost in transit
    assert list(st.buf) == [3, 5]         # capacity eviction of 1
    assert st.n_evicted == 1

    # a blocked waiter must NOT be resumed by a dropped item
    got = []

    def reader(eng, store):
        got.append((yield ("get_timeout", store("ch"), 5.0)))

    def writer(eng, store):
        store("ch").drop_filter = lambda item: item == "lost"
        yield ("sleep", 1.0)
        store("ch").put("lost")
        yield ("sleep", 1.0)
        store("ch").put("kept")

    _, stores = _run(reader, writer)
    assert got == ["kept"]
    assert stores["ch"].n_dropped == 1
