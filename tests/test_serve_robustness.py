"""Robustness layer of the serving subsystem (ISSUE 10).

Pins the overload/fault contracts of `src/repro/serve/`:
  * no scheduler exit path leaves a future unresolved — normal drain,
    max_steps abort, per-request validation failure, engine crash;
  * admission control: bounded queue reject/block semantics, submit-side
    shape validation, queue-side deadline shedding, running-slot
    deadline preemption (finish_reason taxonomy);
  * deterministic serve-side faults (`serve/faults.py`): stalls/drift,
    transient step failures (retried, bit-identical output), fatal
    crashes;
  * crash recovery (`run_with_recovery`): the engine is rebuilt, the
    in-flight requests replay from their prompts, and the outputs are
    token-for-token identical to the fault-free run — the acceptance
    criterion of the PR.
"""
import threading
import time

import numpy as np
import pytest

from repro.serve import (EngineCrashed, QueueClosed, QueueFull,
                         RecoveryGaveUp, Request, RequestQueue,
                         RequestRejected, SchedulerAborted, ServeEngine,
                         ServeFaultPlan, StepStall, StragglerDrift,
                         run_with_recovery)


def make_requests(vocab, n, *, gen=6, seed0=0, **kw):
    rng = np.random.default_rng(7)
    return [
        Request(prompt=rng.integers(0, vocab, size=(int(rng.integers(3, 9)),)),
                max_new_tokens=gen, seed=seed0 + i, **kw)
        for i in range(n)
    ]


def clone(req, **kw):
    base = dict(prompt=np.asarray(req.prompt),
                max_new_tokens=req.max_new_tokens,
                temperature=req.temperature, seed=req.seed,
                eos_id=req.eos_id, x_a=req.x_a, deadline_s=req.deadline_s)
    base.update(kw)
    return Request(**base)


@pytest.fixture(scope="module")
def qwen():
    """Warmed engine: the (qwen2, 4, 32) slot program is compiled before
    any timing-sensitive (deadline) test runs."""
    eng = ServeEngine("qwen2-0.5b", slots=4, cache_cap=32, seed=0)
    eng.serve([Request(prompt=[1, 2, 3], max_new_tokens=2)])
    return eng


def fresh(qwen, **kw):
    """Engine sharing qwen's params + compiled program (same shape)."""
    return ServeEngine("qwen2-0.5b", slots=4, cache_cap=32,
                       params=qwen.params, **kw)


# ---------------------------------------------------------------------------
# request / fault-plan validation units
# ---------------------------------------------------------------------------
def test_request_validation():
    with pytest.raises(ValueError):
        Request(prompt=[1], temperature=-0.1)
    with pytest.raises(ValueError):
        Request(prompt=[1], deadline_s=0.0)
    r = Request(prompt=[1], deadline_s=2.0)
    assert r.deadline == r.t_submit + 2.0
    assert not r.expired(r.t_submit + 1.0)
    assert r.expired(r.t_submit + 2.5)
    assert Request(prompt=[1]).deadline is None


def test_fault_plan_validation_and_roundtrip():
    with pytest.raises(ValueError):
        ServeFaultPlan(stalls=(StepStall(at_step=-1, stall_s=0.1),))
    with pytest.raises(ValueError):
        ServeFaultPlan(drift=StragglerDrift(per_step_s=-1e-3))
    with pytest.raises(ValueError):
        ServeFaultPlan(crashes=(-2,))
    plan = ServeFaultPlan(
        stalls=(StepStall(at_step=3, stall_s=0.01),),
        drift=StragglerDrift(start_step=2, per_step_s=1e-4, cap_s=0.05),
        step_fails=(5,), crashes=(9, 20), poison_rids=(1,))
    assert not plan.empty
    assert ServeFaultPlan().empty
    back = ServeFaultPlan.from_dict(plan.to_dict())
    assert back == plan
    # stall accounting: one-off + capped drift
    assert plan.stall_s_at(3) == pytest.approx(0.01 + 1e-4)
    assert plan.stall_s_at(1000) == pytest.approx(0.05)
    # one-shot semantics
    assert plan.take_step_failure(5) and not plan.take_step_failure(5)
    assert back.poisoned(1) and not back.poisoned(0)


# ---------------------------------------------------------------------------
# RequestQueue: bounded capacity + concurrency
# ---------------------------------------------------------------------------
def test_queue_bounded_reject():
    q = RequestQueue(capacity=2, policy="reject")
    q.submit(Request(prompt=[1]))
    q.submit(Request(prompt=[2]))
    with pytest.raises(QueueFull) as ei:
        q.submit(Request(prompt=[3]))
    assert ei.value.capacity == 2
    assert len(q) == 2                       # rejected offer not queued
    q.try_get()
    q.submit(Request(prompt=[4]))            # space freed -> accepted


def test_queue_bounded_block_unblocks_on_pop():
    q = RequestQueue(capacity=1, policy="block")
    q.submit(Request(prompt=[1]))
    submitted = []

    def producer():
        submitted.append(q.submit(Request(prompt=[2])))

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not submitted                     # parked on the full queue
    assert q.try_get() is not None
    t.join(timeout=5.0)
    assert not t.is_alive() and len(submitted) == 1


def test_queue_bounded_block_raises_on_close():
    q = RequestQueue(capacity=1, policy="block")
    q.submit(Request(prompt=[1]))
    err = []

    def producer():
        try:
            q.submit(Request(prompt=[2]))
        except QueueClosed as e:
            err.append(e)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    q.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and len(err) == 1


def test_queue_multi_producer_rids_unique():
    q = RequestQueue()
    n_threads, per = 8, 25

    def producer(k):
        for i in range(per):
            q.submit(Request(prompt=[k, i]))

    ts = [threading.Thread(target=producer, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    rids = []
    while True:
        r = q.try_get()
        if r is None:
            break
        rids.append(r.rid)
    assert sorted(rids) == list(range(n_threads * per))


def test_queue_submit_close_race():
    """Racing submits against close: every submit either lands in the
    queue or raises QueueClosed — nothing is lost or duplicated."""
    q = RequestQueue()
    outcomes = {"ok": 0, "closed": 0}
    lock = threading.Lock()

    def producer():
        for i in range(50):
            try:
                q.submit(Request(prompt=[i + 1]))
                with lock:
                    outcomes["ok"] += 1
            except QueueClosed:
                with lock:
                    outcomes["closed"] += 1

    ts = [threading.Thread(target=producer) for _ in range(4)]
    for t in ts:
        t.start()
    time.sleep(0.002)
    q.close()
    for t in ts:
        t.join()
    assert outcomes["ok"] + outcomes["closed"] == 200
    assert len(q) == outcomes["ok"]


def test_queue_wait_close_race():
    """A scheduler parked in wait() returns promptly when the queue
    closes under it (no deadlock, no full timeout burn)."""
    q = RequestQueue()
    waited = []

    def scheduler():
        t0 = time.perf_counter()
        q.wait(30.0)
        waited.append(time.perf_counter() - t0)

    t = threading.Thread(target=scheduler, daemon=True)
    t.start()
    time.sleep(0.05)
    q.close()
    t.join(timeout=5.0)
    assert not t.is_alive()
    assert waited and waited[0] < 5.0


def test_queue_requeue_preserves_stamps():
    q = RequestQueue()
    f0 = q.submit(Request(prompt=[1]))
    f1 = q.submit(Request(prompt=[2]))
    a, b = q.try_get(), q.try_get()
    q.requeue([a, b])
    a2, b2 = q.try_get(), q.try_get()
    assert (a2.rid, b2.rid) == (0, 1)        # front, original order
    assert a2.future is f0 and b2.future is f1


# ---------------------------------------------------------------------------
# per-request validation: reject at submit, fail-only-that-future at admit
# ---------------------------------------------------------------------------
def test_overflow_rejected_at_submit(qwen):
    q = qwen.queue()
    with pytest.raises(RequestRejected) as ei:
        q.submit(Request(prompt=list(range(1, 30)), max_new_tokens=8))
    assert ei.value.reason == "overflow"
    assert q.empty()
    # boundary: exactly cache_cap fits
    q.submit(Request(prompt=list(range(1, 25)), max_new_tokens=8))


def test_overflow_caught_at_admit(qwen):
    """An oversized request through a plain (unvalidated) queue used to
    silently wrap the slot's KV ring and emit garbage — now it comes
    back as a structured error completion, and the batch survives."""
    good = Request(prompt=[3, 1, 4, 1], max_new_tokens=4)
    big = Request(prompt=list(range(1, 30)), max_new_tokens=8)
    out = qwen.serve([clone(good), big, clone(good)])
    assert [c.finish_reason for c in out] == ["length", "error", "length"]
    assert "overflow" in out[1].error and out[1].tokens == []
    assert out[0].tokens == out[2].tokens


def test_bad_xa_fails_only_that_request(qwen):
    solo = qwen.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=4)])[0]
    bad = Request(prompt=[5, 5], max_new_tokens=4,
                  x_a=np.zeros(qwen.cfg.d_active + 3, np.float32))
    out = qwen.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=4),
                      bad,
                      Request(prompt=[3, 1, 4, 1], max_new_tokens=4)])
    assert [c.finish_reason for c in out] == ["length", "error", "length"]
    assert "bad_x_a" in out[1].error
    assert out[0].tokens == solo.tokens == out[2].tokens
    # the same request is also rejected synchronously at a wired queue
    with pytest.raises(RequestRejected):
        qwen.queue().submit(clone(bad))


def test_poisoned_request_fails_only_its_future(qwen):
    eng = fresh(qwen, faults=ServeFaultPlan(poison_rids=(1,)))
    reqs = make_requests(eng.cfg.vocab_size, 3, gen=4)
    out = eng.serve(reqs)
    assert [c.finish_reason for c in out] == ["length", "error", "length"]
    assert "poisoned" in out[1].error
    assert out[0].tokens == qwen.serve([clone(reqs[0])])[0].tokens


# ---------------------------------------------------------------------------
# deadlines: queue-side shed + running-slot preemption
# ---------------------------------------------------------------------------
def test_deadline_shed_from_queue(qwen):
    normal = Request(prompt=[3, 1, 4, 1], max_new_tokens=4)
    doomed = Request(prompt=[2, 7, 1], max_new_tokens=4, deadline_s=1e-9)
    out = qwen.serve([clone(normal), doomed])
    assert out[0].finish_reason == "length"
    assert out[1].finish_reason == "expired" and out[1].tokens == []
    assert qwen.last_run_stats["shed_expired"] == 1


def test_deadline_preempts_running_slot(qwen):
    """A stall fault pushes wall-clock past the deadline mid-decode: the
    slot is preempted with its partial tokens, finish_reason="expired".
    Deterministic: the 0.3 s injected stall always overshoots the
    0.15 s deadline, while the healthy steps before it stay ~ms."""
    ref = qwen.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=20,
                              seed=5)])[0]
    eng = fresh(qwen, faults=ServeFaultPlan(
        stalls=(StepStall(at_step=6, stall_s=0.3),)))
    out = eng.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=20,
                             seed=5, deadline_s=0.15)])[0]
    assert out.finish_reason == "expired"
    assert 0 < len(out.tokens) < 20
    assert out.tokens == ref.tokens[:len(out.tokens)]   # prefix parity
    assert eng.last_run_stats["preempted"] == 1


# ---------------------------------------------------------------------------
# exit paths: no future is ever left hanging
# ---------------------------------------------------------------------------
def test_max_steps_abort_resolves_everything(qwen):
    """Regression for the PR-8 behaviour where exceeding max_steps
    raised with every in-flight and queued future unresolved forever."""
    eng = fresh(qwen)
    q = RequestQueue()
    futs = [q.submit(r) for r in
            make_requests(eng.cfg.vocab_size, 6, gen=20)]
    q.close()
    with pytest.raises(SchedulerAborted):
        eng.run(q, max_steps=5)
    assert all(f.done() for f in futs)       # nobody hangs
    reasons = [f.result().finish_reason for f in futs]
    assert all(r == "aborted" for r in reasons)
    # queued-but-never-admitted requests abort with no tokens
    assert any(len(f.result().tokens) == 0 for f in futs)
    assert q.closed


def test_crash_fails_futures_by_default(qwen):
    eng = fresh(qwen, faults=ServeFaultPlan(crashes=(5,)))
    q = RequestQueue()
    futs = [q.submit(r) for r in
            make_requests(eng.cfg.vocab_size, 6, gen=6)]
    q.close()
    with pytest.raises(EngineCrashed):
        eng.run(q)
    assert all(f.done() for f in futs)
    assert all(isinstance(f.exception(), EngineCrashed) for f in futs)


# ---------------------------------------------------------------------------
# deterministic faults: transient step failure, crash + recovery replay
# ---------------------------------------------------------------------------
def test_step_failure_retried_bit_identical(qwen):
    reqs = make_requests(qwen.cfg.vocab_size, 5, gen=6)
    ref = qwen.serve([clone(r) for r in reqs])
    eng = fresh(qwen, faults=ServeFaultPlan(step_fails=(2, 7)))
    out = eng.serve([clone(r) for r in reqs])
    assert [c.tokens for c in out] == [c.tokens for c in ref]
    assert eng.last_run_stats["step_retries"] == 2
    assert all(c.ok for c in out)


def test_crash_recovery_replays_token_for_token(qwen):
    """THE acceptance criterion: an engine crash mid-batch, recovered by
    run_with_recovery, completes every submitted request with outputs
    token-for-token identical to the fault-free run."""
    reqs = make_requests(qwen.cfg.vocab_size, 6, gen=6, seed0=20)
    ref = qwen.serve([clone(r) for r in reqs])

    eng = fresh(qwen, faults=ServeFaultPlan(crashes=(10,)))
    q = eng.queue()
    futs = [q.submit(clone(r)) for r in reqs]
    q.close()
    res = run_with_recovery(eng, q, max_restarts=3, backoff_s=0.0)
    assert res.restarts == 1 and len(res.recovery_s) == 1
    assert all(f.done() for f in futs)
    out = res.completions
    assert len(out) == len(reqs)
    assert [c.tokens for c in out] == [c.tokens for c in ref]
    assert all(c.ok for c in out)
    # the futures resolve to the same completions
    assert [f.result().tokens for f in futs] == [c.tokens for c in ref]


def test_recovery_survives_multiple_crashes(qwen):
    reqs = make_requests(qwen.cfg.vocab_size, 6, gen=6, seed0=40)
    ref = qwen.serve([clone(r) for r in reqs])
    eng = fresh(qwen, faults=ServeFaultPlan(crashes=(8, 6)))
    q = eng.queue()
    for r in reqs:
        q.submit(clone(r))
    q.close()
    res = run_with_recovery(eng, q, max_restarts=4, backoff_s=0.0)
    assert res.restarts == 2
    assert [c.tokens for c in res.completions] == [c.tokens for c in ref]


def test_recovery_gives_up_and_fails_futures(qwen):
    eng = fresh(qwen, faults=ServeFaultPlan(crashes=(0, 0, 0, 0, 0)))
    q = eng.queue()
    futs = [q.submit(r) for r in
            make_requests(eng.cfg.vocab_size, 4, gen=4, seed0=60)]
    q.close()
    with pytest.raises(RecoveryGaveUp):
        run_with_recovery(eng, q, max_restarts=2, backoff_s=0.0)
    assert all(f.done() for f in futs)
    assert all(isinstance(f.exception(), (RecoveryGaveUp, EngineCrashed))
               for f in futs)


def test_drift_and_stall_accounted(qwen):
    eng = fresh(qwen, faults=ServeFaultPlan(
        stalls=(StepStall(at_step=1, stall_s=0.02),),
        drift=StragglerDrift(start_step=0, per_step_s=1e-4, cap_s=0.002)))
    out = eng.serve([Request(prompt=[3, 1, 4, 1], max_new_tokens=4)])
    assert out[0].ok
    assert eng.last_run_stats["injected_stall_s"] >= 0.02
