"""Dense tick packing: the packed lane layout must be a pure re-timing
of the dense tick program.

The packed compiler may move ops to different ticks (capacity spill,
pb->pf fusion) and may assign different ring slots, but it must preserve
everything the replay's numerics depend on:

* each replica executes exactly the same (phase, batch) sequence, in the
  same order (ticks are scanned in order; within a tick the engine runs
  pb, then pf, then as — the decode below mirrors that);
* the overall (phase, replica, batch) multiset is identical;
* producer->consumer dataflow is well formed under the engine's
  within-tick phase ordering: an a_step reads the embedding slot its
  batch's p_fwd wrote (same tick allowed: pf phase precedes as), and a
  p_bwd reads the gradient slot its batch's a_step wrote from a strictly
  later tick (pb phase precedes as within a tick);
* compile-time byproducts (staleness, update count, final versions) are
  identical.

Plus the headline regression: packed lane occupancy on the synthetic
pubsub log stays >= 90% (the dense layout sits near 50%)."""
import numpy as np
import pytest

from repro.core.cost_model import PartyProfile, SystemProfile
from repro.core.des import METHODS, RunConfig, simulate
from repro.core.schedule import compile_schedule
from repro.data.synthetic import load
from repro.data.vertical import psi_align, vertical_split

N_REP = 4


def _sim(method, n_epochs=3, batch_size=64, dataset="credit", scale=0.05):
    ds = load(dataset, scale=scale)
    tr, _ = ds.split()
    a_tr, p_tr = vertical_split(tr)
    a_tr, p_tr = psi_align(a_tr, p_tr)
    prof = SystemProfile(active=PartyProfile(cores=32),
                         passive=PartyProfile(cores=32))
    cfg = RunConfig(method=method, n_samples=a_tr.X.shape[0],
                    batch_size=batch_size, n_epochs=n_epochs, w_a=N_REP,
                    w_p=N_REP, profile=prof)
    return cfg, simulate(cfg), a_tr.X.shape[0]


def _compile(cfg, sim, n_samples, pack):
    return compile_schedule(cfg, sim.events, n_rep_a=N_REP, n_rep_p=N_REP,
                            n_samples=n_samples, pack=pack)


def _decode_segmented(sched):
    """Walk a segmented schedule's run chain in engine order (runs back
    to back, pb -> pf -> as within a tick)."""
    seqs, multi, ops = {}, [], []
    tick0 = 0
    for seg in sched.segments:
        for run in seg.runs:
            T = run.n_ticks
            for t in range(T):
                for ph in ("pb", "pf", "as"):     # engine phase order
                    if ph not in run.sig:
                        continue
                    rep_arr = run.arrays[f"{ph}_rep"]
                    bid_arr = run.arrays[f"{ph}_bid"]
                    for j in range(rep_arr.shape[1]):
                        rep = int(rep_arr[t, j])
                        if rep < 0:
                            continue
                        bid = int(bid_arr[t, j])
                        if ph == "as":
                            slots = (int(run.arrays["as_eslot"][t, j]),
                                     int(run.arrays["as_gslot"][t, j]))
                        else:
                            slots = (int(run.arrays[f"{ph}_slot"][t, j]),)
                        party = "p" if ph in ("pf", "pb") else "a"
                        seqs.setdefault((party, rep), []).append((ph, bid))
                        multi.append((ph, rep, bid))
                        ops.append((tick0 + t, ph, rep, bid, slots))
            tick0 += T
    return seqs, sorted(multi), ops


def _decode(sched):
    """Walk the tick program in engine order; return per-replica op
    sequences, the global op multiset, and per-op (tick, slots)."""
    if sched.pack == "segmented":
        return _decode_segmented(sched)
    packed = sched.pack == "packed"
    seqs, multi, ops = {}, [], []
    tick0 = 0
    for seg in sched.segments:
        T = seg.agg_a.shape[0]
        for t in range(T):
            for ph in ("pb", "pf", "as"):        # engine phase order
                bid_arr = getattr(seg, f"{ph}_bid")
                rep_arr = getattr(seg, f"{ph}_rep") if packed else None
                for j in range(bid_arr.shape[1]):
                    if packed:
                        rep = int(rep_arr[t, j])
                        if rep < 0:
                            continue
                    else:
                        if bid_arr[t, j] < 0:
                            continue
                        rep = j
                    bid = int(bid_arr[t, j])
                    if ph == "as":
                        slots = (int(seg.as_eslot[t, j]),
                                 int(seg.as_gslot[t, j]))
                    else:
                        slots = (int(getattr(seg, f"{ph}_slot")[t, j]),)
                    party = "p" if ph in ("pf", "pb") else "a"
                    seqs.setdefault((party, rep), []).append((ph, bid))
                    multi.append((ph, rep, bid))
                    ops.append((tick0 + t, ph, rep, bid, slots))
        tick0 += T
    return seqs, sorted(multi), ops


@pytest.mark.parametrize("method", METHODS)
def test_packed_decodes_to_same_replica_streams(method):
    """Packed and dense schedules decode to identical per-replica
    (phase, batch) sequences and identical op multisets; ticks and ring
    slots are layout-private."""
    cfg, sim, n = _sim(method)
    dense = _compile(cfg, sim, n, "dense")
    packed = _compile(cfg, sim, n, "packed")
    seq_d, multi_d, _ = _decode(dense)
    seq_p, multi_p, _ = _decode(packed)
    assert seq_p == seq_d
    assert multi_p == multi_d
    # compile-time byproducts the trainer reports must not change
    assert packed.staleness == dense.staleness
    assert packed.n_updates == dense.n_updates
    assert packed.versions_p == dense.versions_p
    assert packed.has_inscan_agg == dense.has_inscan_agg
    assert [s.epoch_agg for s in packed.segments] == \
        [s.epoch_agg for s in dense.segments]


@pytest.mark.parametrize("method", METHODS)
def test_segmented_decodes_to_packed_event_order(method):
    """The segmented layout is a pure re-grouping of the packed tick
    stream: the decoded per-replica (phase, batch) sequences and the op
    multiset replay the exact event order of the packed decode; run
    boundaries and per-run lane widths are layout-private.  Compile-time
    byproducts are identical too."""
    cfg, sim, n = _sim(method)
    packed = _compile(cfg, sim, n, "packed")
    seg = _compile(cfg, sim, n, "segmented")
    seq_p, multi_p, _ = _decode(packed)
    seq_s, multi_s, _ = _decode(seg)
    assert seq_s == seq_p
    assert multi_s == multi_p
    assert seg.staleness == packed.staleness
    assert seg.n_updates == packed.n_updates
    assert seg.versions_p == packed.versions_p
    assert seg.has_inscan_agg == packed.has_inscan_agg
    assert [s.epoch_agg for s in seg.segments] == \
        [s.epoch_agg for s in packed.segments]


def test_segmented_runs_trace_only_their_signature():
    """Cond-free bodies rely on two structural guarantees: a run's
    arrays cover exactly its signature (absent phases are not
    materialized, so the engine cannot trace them), and every phase in
    the signature has at least one live lane in the run (the partition
    never charges a phase that never fires)."""
    cfg, sim, n = _sim("pubsub")
    sched = _compile(cfg, sim, n, "segmented")
    for seg in sched.segments:
        for run in seg.runs:
            for ph in ("pb", "pf", "as"):
                present = f"{ph}_rep" in run.arrays
                assert present == (ph in run.sig)
                if present:
                    assert (run.arrays[f"{ph}_rep"] >= 0).any()
            has_flags = "agg_a" in run.arrays
            assert has_flags == run.has_agg
            if run.has_agg:
                assert (run.arrays["agg_a"] | run.arrays["agg_p"]).any()


@pytest.mark.parametrize("pack", ["dense", "packed", "segmented"])
def test_ring_dataflow_well_formed(pack):
    """Replaying the slot assignments against the engine's within-tick
    phase order must hand every consumer its own producer's payload."""
    cfg, sim, n = _sim("pubsub")
    sched = _compile(cfg, sim, n, pack)
    _, _, ops = _decode(sched)
    emb = {}     # slot -> (bid, write tick)
    grad = {}    # slot -> (bid, write tick)
    # ops come out in execution order (tick, then pb < pf < as)
    for t, ph, rep, bid, slots in ops:
        if ph == "pf":
            emb[slots[0]] = (bid, t)
        elif ph == "as":
            e, g = slots
            got, tw = emb[e]
            assert got == bid and tw <= t       # same tick: pf before as
            grad[g] = (bid, t)
        else:
            got, tw = grad[slots[0]]
            assert got == bid and tw < t        # pb phase precedes as
    assert max(emb, default=0) < sched.emb_slots
    assert max(grad, default=0) < sched.grad_slots


@pytest.mark.parametrize("pack", ["packed", "segmented"])
def test_packed_replica_appears_once_per_phase_per_tick(pack):
    """The engine's merge-back is only conflict-free if a replica holds
    at most one lane per phase per tick."""
    cfg, sim, n = _sim("pubsub")
    sched = _compile(cfg, sim, n, pack)
    if pack == "segmented":
        rep_arrays = [(ph, run.arrays[f"{ph}_rep"])
                      for seg in sched.segments for run in seg.runs
                      for ph in run.sig]
    else:
        rep_arrays = [(ph, getattr(seg, f"{ph}_rep"))
                      for seg in sched.segments
                      for ph in ("pf", "pb", "as")]
    for _, rep in rep_arrays:
        for t in range(rep.shape[0]):
            live = rep[t][rep[t] >= 0]
            assert len(live) == len(set(live.tolist()))


def test_packed_occupancy_regression_pubsub():
    """>= 90% executed-lane occupancy on the synthetic pubsub log (the
    benchmark config of benchmarks/replay_throughput.py), vs ~50%
    dense.  Occupancy counts lanes of phases the engine actually runs —
    all-idle phases are cond-skipped (see CompiledSchedule
    .lane_occupancy)."""
    cfg, sim, n = _sim("pubsub", n_epochs=5, dataset="synthetic",
                       scale=0.02, batch_size=256)
    dense = _compile(cfg, sim, n, "dense")
    packed = _compile(cfg, sim, n, "packed")
    seg = _compile(cfg, sim, n, "segmented")
    assert packed.lane_occupancy() >= 0.90
    assert seg.lane_occupancy() >= 0.90
    assert dense.lane_occupancy() <= 0.70
    # and packing must actually shrink the executed work
    d_slots = sum(dense.n_ops()) / max(dense.lane_occupancy(), 1e-9)
    p_slots = sum(packed.n_ops()) / max(packed.lane_occupancy(), 1e-9)
    assert p_slots < 0.75 * d_slots


def test_segmented_occupancy_at_unit_widths_pubsub(monkeypatch):
    """The run partitioner recovers the warmup/drain bubbles: with the
    lane budget pinned to width 1 (where lanes are full by
    construction and all residual waste is phase-starvation ticks),
    executed-lane occupancy on the synthetic pubsub benchmark config
    reaches >= 0.98 — vs ~0.95 for a single uniform-width segment.

    The default objective deliberately does NOT pick this program: at
    B=256 the width-2 schedule is ~1.3x faster on CPU despite its ~0.91
    occupancy (fewer, wider ticks amortize the per-tick fixed cost
    better than fuller lanes repay), which is exactly the trade the
    schedule-length-aware cost model makes.  See
    docs/architecture.md §occupancy."""
    from repro.core import schedule as S
    cfg, sim, n = _sim("pubsub", n_epochs=5, dataset="synthetic",
                       scale=0.02, batch_size=256)
    packed = _compile(cfg, sim, n, "packed")      # before pinning caps
    caps = {"pf": 1, "pb": 1, "as": 1}
    monkeypatch.setattr(S, "_cap_candidates", lambda low, a, p: [caps])
    S._SCHEDULE_MEMO.clear()
    seg = compile_schedule(cfg, sim.events, n_rep_a=N_REP, n_rep_p=N_REP,
                           n_samples=n, pack="segmented")
    S._SCHEDULE_MEMO.clear()     # do not leak the pinned-caps schedule
    assert seg.lane_widths == (1, 1, 1)
    assert seg.lane_occupancy() >= 0.98
    # the decoded program is still the same event order
    seq_s, multi_s, _ = _decode(seg)
    seq_p, multi_p, _ = _decode(packed)
    assert seq_s == seq_p and multi_s == multi_p
