"""Deterministic fault injection: faulty worlds replay bit-for-bit.

A `FaultPlan` (replica crash+rejoin, straggler cadence drift, channel
drop bursts) is consumed by the DES so every fault lands in the event
log deterministically; the schedule compiler lowers dead replicas into
masked lanes and live-subset aggregation boundaries.  The contract under
test: a faulty world is just another event log, so it replays the same
across engine={compiled,event}, pack={segmented,packed}, DP on/off and
device counts — same tolerances the healthy parity suite pins.

This file is its own mesh worker entry point (test_mesh_replay idiom)::

    python tests/test_faults.py parity '<json payload>'
"""
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (ChannelDropFault, CrashFault, ExperimentConfig,
                       FaultPlan, Session, StragglerFault)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")

BASE = dict(method="pubsub", dataset="credit", scale=0.05, n_epochs=3,
            batch_size=64, w_a=4, w_p=4)

# the healthy BASE sim spans ~0.55 time units; every fault window below
# is tuned to land mid-run (crashes fire, rejoins cross epoch
# boundaries) — test_fault_stats_recorded pins that they all fired
CRASH = FaultPlan(crashes=(
    CrashFault(side="p", replica=1, at=0.15, rejoin_after=0.2),
    CrashFault(side="a", replica=2, at=0.25, rejoin_after=0.15)))
STRAGGLE = FaultPlan(stragglers=(
    StragglerFault(side="a", replica=0, factor=2.5, start=0.1, ramp=0.3),
    StragglerFault(side="p", replica=3, factor=1.7, start=0.25)))
PERM = FaultPlan(crashes=(CrashFault(side="p", replica=2, at=0.25),))
DROPS = FaultPlan(drops=(
    ChannelDropFault(channel="emb", start=0.1, duration=0.3,
                     drop_every=3),
    ChannelDropFault(channel="grad", start=0.25, duration=0.2,
                     drop_every=4)))

SCENARIOS = {"crash_rejoin": CRASH, "straggler": STRAGGLE,
             "perm_crash": PERM, "chan_drop": DROPS}


# ---------------------------------------------------------------------------
# FaultPlan semantics (pure data, no sim)
# ---------------------------------------------------------------------------
def test_faultplan_validation():
    with pytest.raises(ValueError, match="side"):
        FaultPlan(crashes=(CrashFault(side="x", replica=0, at=1.0),))
    with pytest.raises(ValueError, match="replica"):
        FaultPlan(stragglers=(StragglerFault(side="a", replica=-1),))
    with pytest.raises(ValueError, match="rejoin_after"):
        FaultPlan(crashes=(CrashFault(side="a", replica=0, at=1.0,
                                      rejoin_after=0.0),))
    with pytest.raises(ValueError, match="channel"):
        FaultPlan(drops=(ChannelDropFault(channel="ctrl", start=0.0,
                                          duration=1.0),))
    with pytest.raises(ValueError, match="drop_every"):
        FaultPlan(drops=(ChannelDropFault(channel="emb", start=0.0,
                                          duration=1.0, drop_every=0),))
    # method-dependent semantics
    DROPS.validate("pubsub")
    with pytest.raises(ValueError, match="pubsub"):
        DROPS.validate("vfl_ps")
    PERM.validate("pubsub")
    with pytest.raises(ValueError, match="rejoin"):
        PERM.validate("vfl_ps")          # never-rejoining stall
    CRASH.validate("vfl_ps")             # finite outages stall fine


def test_faultplan_roundtrip_and_key():
    for fp in SCENARIOS.values():
        back = FaultPlan.from_dict(json.loads(json.dumps(fp.to_dict())))
        assert back == fp and back.key() == fp.key()
    assert FaultPlan().empty and not CRASH.empty
    assert CRASH.key() != STRAGGLE.key()
    assert {CRASH: 1}[FaultPlan.from_dict(CRASH.to_dict())] == 1


def test_straggler_multiplier_ramp():
    fp = FaultPlan(stragglers=(
        StragglerFault(side="a", replica=0, factor=3.0, start=1.0,
                       ramp=2.0),))
    assert fp.multiplier("a", 0, 0.5) == 1.0       # before start
    assert fp.multiplier("a", 0, 1.0) == 1.0       # at start
    assert fp.multiplier("a", 0, 2.0) == 2.0       # mid-ramp
    assert fp.multiplier("a", 0, 3.0) == 3.0       # ramp done
    assert fp.multiplier("a", 0, 99.0) == 3.0      # stays
    assert fp.multiplier("p", 0, 2.0) == 1.0       # other replica
    # step change and compounding
    step = FaultPlan(stragglers=(
        StragglerFault(side="p", replica=1, factor=2.0, start=1.0),
        StragglerFault(side="p", replica=1, factor=1.5, start=2.0)))
    assert step.multiplier("p", 1, 1.5) == 2.0
    assert step.multiplier("p", 1, 2.5) == 3.0


# ---------------------------------------------------------------------------
# DES: faults land in the event log deterministically
# ---------------------------------------------------------------------------
def _session(**kw):
    d = dict(BASE)
    d.update(kw)
    return Session(ExperimentConfig(**d))


_CACHE = {}


def _run(key, **kw):
    """Memoized Session runs — several tests compare against the same
    healthy/faulty reference."""
    if key not in _CACHE:
        sess = _session(**kw)
        _CACHE[key] = (sess, sess.run())
    return _CACHE[key]


def test_empty_plan_is_the_healthy_world():
    """faults=None and an empty FaultPlan produce the identical event
    log and bit-identical training — the healthy path has no fault tax."""
    s0, r0 = _run("healthy")
    s1, r1 = _run("empty_plan", faults=FaultPlan())
    assert s0.compile().sim.events == s1.compile().sim.events
    assert r1.train.losses == r0.train.losses
    assert r1.train.history == r0.train.history
    assert r1.train.final_metric == r0.train.final_metric


def test_faulty_log_is_deterministic():
    """Same seed + same plan -> byte-identical events and training, DP
    included (faults must not perturb the noise stream alignment)."""
    a_s, a = _run("det_a", faults=CRASH, dp_mu=0.5)
    b_s, b = _run("det_b", faults=CRASH, dp_mu=0.5)
    assert a_s.compile().sim.events == b_s.compile().sim.events
    assert a.train.losses == b.train.losses
    assert a.train.history == b.train.history
    kinds = {e[1] for e in a_s.compile().sim.events}
    assert {"crash", "rejoin"} <= kinds


def test_fault_stats_recorded():
    sess, _ = _run("det_a", faults=CRASH, dp_mu=0.5)
    fs = sess.compile().sim.stats["faults"]
    assert fs["crashes"] == 2 and fs["rejoins"] == 2
    assert all(s > 0 for s in fs["rejoin_staleness"])
    dsess, _ = _run("drops", faults=DROPS)
    assert dsess.compile().sim.stats["faults"]["chan_dropped"] > 0


def test_structural_key_isolates_fault_plans():
    """A fault plan reshapes the lowered program, so faulty configs must
    never share a compiled program with healthy ones."""
    s0, _ = _run("healthy")
    s1, _ = _run("det_a", faults=CRASH, dp_mu=0.5)
    assert s0.structural_key() != s1.structural_key()


def test_drops_require_deadline():
    with pytest.raises(ValueError, match="t_ddl"):
        _session(faults=DROPS, disable_deadline=True).run()


def test_drops_rejected_off_pubsub_at_session_init():
    with pytest.raises(ValueError, match="pubsub"):
        _session(method="vfl_ps", faults=DROPS)


# ---------------------------------------------------------------------------
# lowering: dead replicas become masked lanes + live-subset boundaries
# ---------------------------------------------------------------------------
def test_lowering_masks_and_rejoins():
    sess, _ = _run("det_a", faults=CRASH, dp_mu=0.5)
    sched = sess.compile().engine.schedule
    assert len(sched.epoch_live) == BASE["n_epochs"]
    subsets = [lv for lv in sched.epoch_live if lv is not None]
    assert subsets, "crash window never overlapped an epoch boundary"
    for live_a, live_p in subsets:
        assert 0 < len(live_a) <= BASE["w_a"]
        assert 0 < len(live_p) <= BASE["w_p"]
    # both replicas rejoined, with recorded (positive) staleness
    assert sorted(s for s, _, _ in [(r[0], r[1], r[2])
                                    for r in sched.rejoins]) == ["a", "p"]
    assert all(r[2] > 0 for r in sched.rejoins)
    assert sched.final_live is None      # everyone is back at the end
    # the event engine derives the SAME live sets from the same log
    ev = _session(engine="event", faults=CRASH, dp_mu=0.5)
    eng = ev.compile().engine
    assert tuple(eng._live) == sched.epoch_live
    assert eng._final_live == sched.final_live


def test_permanent_crash_shrinks_final_live():
    sess, _ = _run("perm", faults=PERM)
    sched = sess.compile().engine.schedule
    assert sched.final_live is not None
    live_a, live_p = sched.final_live
    assert len(live_a) == BASE["w_a"]
    assert live_p == tuple(i for i in range(BASE["w_p"]) if i != 2)
    # survivors absorbed the dead replica's jobs: full step count
    assert sched.n_updates == _run("healthy")[0].compile() \
        .engine.schedule.n_updates


# ---------------------------------------------------------------------------
# engine / pack parity on faulty worlds
# ---------------------------------------------------------------------------
def _assert_engine_parity(rc, re):
    np.testing.assert_allclose(rc.train.losses, re.train.losses,
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(rc.train.history, re.train.history,
                               rtol=1e-3, atol=1e-4)
    assert rc["staleness"] == re["staleness"]
    assert rc.train.final_metric == pytest.approx(re.train.final_metric,
                                                  rel=1e-3, abs=1e-4)


@pytest.mark.parametrize("scenario", ["crash_rejoin", "straggler",
                                      "perm_crash", "chan_drop"])
def test_fault_parity_across_engines_and_packs(scenario):
    """Every fault scenario replays the same across compiled/event and
    segmented/packed.  Noiseless path: DP noise streams are
    engine/layout-local BY CONTRACT (segmented advances the PRNG key
    only on publish ticks — see test_engine_parity), so DP-on
    equivalence is pinned as bitwise same-config determinism below, not
    cross-engine closeness."""
    fp = SCENARIOS[scenario]
    _, seg = _run(("seg", scenario), faults=fp)
    _, ev = _run(("ev", scenario), engine="event", faults=fp)
    _assert_engine_parity(seg, ev)
    _, pk = _run(("pk", scenario), pack="packed", faults=fp)
    np.testing.assert_allclose(seg.train.losses, pk.train.losses,
                               rtol=1e-5)
    np.testing.assert_allclose(seg.train.history, pk.train.history,
                               rtol=1e-5)


@pytest.mark.parametrize("scenario,engine,pack", [
    ("crash_rejoin", "compiled", "segmented"),
    ("crash_rejoin", "compiled", "packed"),
    ("crash_rejoin", "event", None),
    ("straggler", "compiled", "segmented"),
])
def test_fault_dp_replay_is_bitwise_deterministic(scenario, engine,
                                                  pack):
    """DP on: the same faulty config replays bit-identically on every
    engine and lane layout (the faults must not perturb each stream's
    own key advance)."""
    kw = dict(faults=SCENARIOS[scenario], dp_mu=0.5)
    if engine == "event":
        kw["engine"] = "event"
    if pack == "packed":
        kw["pack"] = "packed"
    if (scenario, engine, pack) == ("crash_rejoin", "compiled",
                                    "segmented"):
        ka, kb = "det_a", "det_b"        # shared with the det tests
    else:
        ka, kb = (("dp_a", scenario, engine, pack),
                  ("dp_b", scenario, engine, pack))
    _, a = _run(ka, **kw)
    _, b = _run(kb, **kw)
    assert a.train.losses == b.train.losses
    assert a.train.history == b.train.history
    assert a.train.final_metric == b.train.final_metric


def test_fault_dp_noise_does_not_help():
    """Semantic DP check on a faulty world: heavy noise must not beat
    the noiseless run."""
    _, clean = _run(("seg", "crash_rejoin"), faults=CRASH)
    _, noisy = _run("det_a", faults=CRASH, dp_mu=0.5)
    assert noisy.train.final_metric <= clean.train.final_metric + 0.02


def test_stall_semantics_on_paired_method():
    """On vfl_ps a crash is a stall: barrier partners wait, wall-clock
    blows up, but no work is lost — parity still holds and the step
    count matches the healthy run."""
    fp = FaultPlan(crashes=(
        CrashFault(side="p", replica=1, at=0.3, rejoin_after=0.6),))
    kw = dict(method="vfl_ps", faults=fp)
    hs, _ = _run(("vfl_healthy",), method="vfl_ps")
    cs, rc = _run(("vfl_stall",), **kw)
    es, re = _run(("vfl_stall_ev",), engine="event", **kw)
    _assert_engine_parity(rc, re)
    assert rc["sim_s"] > hs.compile().sim.total_time
    kinds = {e[1] for e in cs.compile().sim.events}
    assert {"stall", "resume"} <= kinds and "crash" not in kinds
    assert cs.compile().engine.schedule.epoch_live == \
        (None,) * BASE["n_epochs"]       # stalls never mask lanes


# ---------------------------------------------------------------------------
# device-count parity: faulty worlds on a forced 4-device mesh
# ---------------------------------------------------------------------------
def _spawn(mode, payload, *, device_count=4, timeout=3000):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{device_count}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), mode,
         json.dumps(payload)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"worker {mode} failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    lines = [l for l in proc.stdout.splitlines()
             if l.startswith("RESULT:")]
    assert lines, f"worker {mode} printed no RESULT line:\n{proc.stdout}"
    return json.loads(lines[-1][len("RESULT:"):])


def _assert_mesh(out):
    assert out["losses_eq"], "1-vs-4 device losses differ"
    assert out["history_eq"], "1-vs-4 device history differs"
    assert out["final_eq"], "1-vs-4 device final metric differs"
    assert not out["bad_leaves"], f"state leaves differ: " \
        f"{out['bad_leaves']}"


def test_mesh_parity_crash_rejoin():
    """Crash+rejoin world, 6 replicas over 4 devices (uneven lanes so
    the dead lane masking crosses device boundaries) — bit-for-bit."""
    out = _spawn("parity", {"overrides": dict(
        n_epochs=2, w_a=6, w_p=6, faults=CRASH.to_dict())})
    _assert_mesh(out)


@pytest.mark.slow
def test_mesh_parity_straggler_dp():
    out = _spawn("parity", {"overrides": dict(
        n_epochs=2, w_a=6, w_p=6, dp_mu=0.5,
        faults=STRAGGLE.to_dict())})
    _assert_mesh(out)


@pytest.mark.slow
def test_mesh_parity_permanent_crash_packed():
    out = _spawn("parity", {"overrides": dict(
        n_epochs=2, w_a=6, w_p=6, pack="packed",
        faults=PERM.to_dict())})
    _assert_mesh(out)


# ---------------------------------------------------------------------------
# worker entry (idiom: this file runs itself under forced device counts)
# ---------------------------------------------------------------------------
def _main(argv):
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from test_mesh_replay import _worker_parity
    mode, payload = argv[0], json.loads(argv[1])
    assert mode == "parity", mode
    print("RESULT:" + json.dumps(_worker_parity(payload)))


if __name__ == "__main__":
    _main(sys.argv[1:])
