"""Unit tests for the paper's core: channels, semi-async schedule, cost
model, planner, and DES invariants."""
import math

import numpy as np
import pytest

from repro.core.channels import (Channel, Message, PubSubBroker,
                                 channel_init, channel_poll,
                                 channel_publish)
from repro.core.cost_model import (TABLE8, CostConstants, CostModel,
                                   PartyProfile, SystemProfile)
from repro.core.des import METHODS, RunConfig, simulate
from repro.core.planner import plan, plan_multiparty
from repro.core.profiler import fit_power_law
from repro.core.semi_async import aggregate, delta_t, sync_epochs


def profile(ca=32, cp=32, **kw):
    return SystemProfile(active=PartyProfile(cores=ca),
                         passive=PartyProfile(cores=cp), **kw)


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------
def test_channel_fifo_eviction():
    ch = Channel(capacity=3)
    for i in range(5):
        ch.publish(Message(i, f"m{i}", float(i)))
    assert ch.n_evicted == 2
    assert [m.batch_id for m in ch.buf] == [2, 3, 4]   # oldest evicted
    assert ch.poll().batch_id == 2


def test_broker_deadline():
    br = PubSubBroker(p=2, q=2, t_ddl=5.0)
    assert br.deadline_expired(0.0, 6.0) is True
    assert br.deadline_expired(0.0, 4.0) is False
    assert br.stats()["deadline_drops"] == 1


def test_broker_topics_independent():
    br = PubSubBroker(p=1, q=1)
    br.publish("emb", 0, "a", 0.0)
    br.publish("emb", 1, "b", 0.0)
    assert br.poll("emb", 1).payload == "b"
    assert br.poll("emb", 0).payload == "a"
    assert br.poll("grad", 0) is None


def test_jit_channel_ring_buffer():
    import jax.numpy as jnp
    st = channel_init(3, (2,))
    for i in range(5):
        st = channel_publish(st, jnp.full((2,), float(i)), i, float(i))
    assert int(st["size"]) == 3
    st, item, bid, valid = channel_poll(st)
    assert bool(valid) and int(bid) == 2          # oldest surviving
    assert float(item[0]) == 2.0
    st, _, bid, _ = channel_poll(st)
    assert int(bid) == 3


# ---------------------------------------------------------------------------
# semi-async schedule (Eq. 5)
# ---------------------------------------------------------------------------
def test_delta_t_eq5_values():
    dt0 = 5
    vals = [delta_t(t, dt0) for t in range(0, 20)]
    # starts small, ramps to dt0, never exceeds, never below 1
    assert vals[0] >= 1
    assert all(1 <= v <= dt0 for v in vals)
    assert vals[-1] == dt0
    assert vals == sorted(vals)                    # monotone ramp
    # literal Eq. 5 at a few points
    for t in (0, 3, 10):
        expected = math.ceil(dt0 / 2 * math.tanh(2 * t / dt0 - 2) + dt0 / 2)
        assert delta_t(t, dt0) == max(expected, 1)


def test_sync_epochs_cover_run():
    marks = sync_epochs(50, 5)
    assert marks[0] >= 1 and marks[-1] <= 50
    assert all(b > a for a, b in zip(marks, marks[1:]))


def test_aggregate_mean():
    import jax.numpy as jnp
    reps = [{"w": jnp.full((2,), float(i))} for i in range(4)]
    agg = aggregate(reps)
    np.testing.assert_allclose(np.asarray(agg["w"]), [1.5, 1.5])


# ---------------------------------------------------------------------------
# cost model + planner
# ---------------------------------------------------------------------------
def test_cost_model_balance_at_defaults():
    cm = CostModel(profile())
    ta = cm.t_f_a(256, 8) + cm.t_b_a(256, 8) + cm.t_top_a(256, 8)
    tp = cm.t_f_p(256, 8) + cm.t_b_p(256, 8)
    assert 0.8 < ta / tp < 1.6        # near-balanced by design (§DESIGN)


def test_table8_constants_verbatim():
    assert TABLE8.lambda_a == 0.018 and TABLE8.gamma_a == -0.8015
    assert TABLE8.beta_p == -1.0546 and TABLE8.scaling_exp == 1.0


def test_b_max_memory_bound():
    prof = profile()
    cm = CostModel(prof)
    bmax = cm.b_max()
    assert cm.mem_a(bmax) <= prof.active.mem_per_worker_mb + 1e-6
    # Eq. 13: raising worker memory raises B_max
    prof2 = SystemProfile(
        active=PartyProfile(cores=32, mem_per_worker_mb=8192),
        passive=PartyProfile(cores=32, mem_per_worker_mb=8192))
    assert CostModel(prof2).b_max() > bmax


def test_planner_optimal_vs_bruteforce():
    prof = profile(16, 8)
    p = plan(prof, w_a_range=(2, 6), w_p_range=(2, 6),
             batch_sizes=(16, 64, 256))
    cm = CostModel(prof)
    best = min(cm.objective(wa, wp, B)
               for wa in range(2, 7) for wp in range(2, 7)
               for B in (16, 64, 256) if B <= cm.b_max())
    assert abs(p.cost - best) < 1e-12


def test_planner_respects_memory():
    prof = SystemProfile(
        active=PartyProfile(cores=32, mem_per_worker_mb=300),
        passive=PartyProfile(cores=32, mem_per_worker_mb=300))
    p = plan(prof, batch_sizes=(16, 32, 64, 1024))
    assert p.batch_size <= p.b_max


def test_plan_multiparty_targets_weakest():
    strong = profile(32, 32)
    weak = profile(32, 4)
    p = plan_multiparty([strong, weak], w_a_range=(2, 8),
                        w_p_range=(2, 8))
    p_weak = plan(weak, w_a_range=(2, 8), w_p_range=(2, 8))
    assert (p.w_a, p.w_p, p.batch_size) == \
        (p_weak.w_a, p_weak.w_p, p_weak.batch_size)


def test_fit_power_law_recovers():
    B = np.array([16, 32, 64, 128, 256])
    lam, gam = 0.02, -0.7
    t = lam * B ** (1 + gam)
    lam2, gam2 = fit_power_law(B, t)
    assert abs(lam2 - lam) < 1e-6 and abs(gam2 - gam) < 1e-6


# ---------------------------------------------------------------------------
# DES invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", METHODS)
def test_des_event_conservation(method):
    cfg = RunConfig(method=method, n_samples=4096, batch_size=256,
                    n_epochs=2, w_a=4, w_p=4, profile=profile())
    r = simulate(cfg)
    kinds = {}
    bids_astep = []
    for t, kind, pl in r.events:
        kinds[kind] = kinds.get(kind, 0) + 1
        if kind == "a_step":
            bids_astep.append(pl["bid"])
    # every batch is a-stepped at most once
    assert len(bids_astep) == len(set(bids_astep))
    # forwards >= a_steps >= backwards-ish; nothing from thin air
    assert kinds.get("a_step", 0) <= kinds.get("p_fwd", 0)
    assert kinds.get("p_bwd", 0) <= kinds.get("a_step", 0)
    assert r.total_time > 0
    assert 0 < r.cpu_util <= 1.0


def test_des_pubsub_processes_all_batches():
    cfg = RunConfig(method="pubsub", n_samples=4096, batch_size=256,
                    n_epochs=3, w_a=4, w_p=4, profile=profile())
    r = simulate(cfg)
    n_asteps = sum(1 for _, k, _ in r.events if k == "a_step")
    assert n_asteps == cfg.n_batches * 3          # no trimming, no loss


def test_des_ordering_speedup():
    """PubSub-VFL is at least ~1.5x faster than pure VFL and has the top
    utilization among methods (paper Fig. 3 ordering)."""
    res = {}
    for m in METHODS:
        cfg = RunConfig(method=m, n_samples=16384, batch_size=256,
                        n_epochs=2, w_a=8, w_p=8, profile=profile())
        res[m] = simulate(cfg)
    assert res["vfl"].total_time / res["pubsub"].total_time > 1.5
    best_util = max(r.cpu_util for r in res.values())
    assert res["pubsub"].cpu_util >= 0.95 * best_util


def test_des_deterministic():
    cfg = RunConfig(method="pubsub", n_samples=4096, batch_size=256,
                    n_epochs=2, w_a=4, w_p=4, profile=profile(), seed=7)
    r1, r2 = simulate(cfg), simulate(cfg)
    assert r1.total_time == r2.total_time
    assert [e[:2] for e in r1.events] == [e[:2] for e in r2.events]
